"""Benchmark harness — one function per paper table/figure (§6).

Prints ``name,us_per_call,derived`` CSV rows.  Wall-clock numbers are
CPU-container numbers; what reproduces the paper is the *relative*
behavior per figure (parallel-fetch speedup, partition-size trade-off,
incremental-vs-version computation, index-size ordering).  BENCH_SCALE
env (default 1.0) scales event counts.

  PYTHONPATH=src python -m benchmarks.run [--only fig11,...]
      [--repeat N] [--json PATH]

``--json PATH`` additionally persists every row as JSON (the BENCH_*.json
perf trajectory committed per PR); ``--repeat`` overrides each bench's
default repeat count (1 = CI smoke mode).
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time
from typing import Callable, Dict, List, Optional

import numpy as np

SCALE = float(os.environ.get("BENCH_SCALE", "1.0"))
N_EVENTS = int(12_000 * SCALE)

REPEAT_OVERRIDE: Optional[int] = None  # set by --repeat
RESULTS: List[Dict] = []  # every _row lands here for --json


def _timeit(fn, repeat=3):
    repeat = REPEAT_OVERRIDE if REPEAT_OVERRIDE is not None else repeat
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us


def _row(name, us, derived=""):
    RESULTS.append({"name": name, "us": round(float(us), 1),
                    "derived": derived})
    print(f"{name},{us:.1f},{derived}", flush=True)


def _build(n_events=None, seed=7, **cfg_kw):
    from repro.core.tgi import TGI, TGIConfig
    from repro.data.temporal_graph_gen import generate
    from repro.storage.kvstore import DeltaStore

    n_events = n_events or N_EVENTS
    events = generate(n_events, seed=seed)
    defaults = dict(n_shards=4, parts_per_shard=2, events_per_span=n_events // 4,
                    eventlist_size=256, checkpoints_per_span=4)
    defaults.update(cfg_kw)
    cfg = TGIConfig(**defaults)
    store = DeltaStore(m=4, r=1, backend="mem")
    tgi = TGI.build(events, cfg, store)
    return events, cfg, store, tgi


# ---------------------------------------------------------------------------


def fig11_snapshot_vs_c():
    """Fig 11: snapshot retrieval vs parallel fetch factor c (file backend
    so threads overlap real I/O)."""
    import tempfile

    from repro.core.tgi import TGI, TGIConfig
    from repro.data.temporal_graph_gen import generate
    from repro.storage.kvstore import DeltaStore

    events = generate(N_EVENTS, seed=7)
    cfg = TGIConfig(n_shards=8, parts_per_shard=2,
                    events_per_span=N_EVENTS // 4, eventlist_size=256)
    with tempfile.TemporaryDirectory() as root:
        store = DeltaStore(m=8, r=1, backend="file", root=root)
        tgi = TGI.build(events, cfg, store)
        t = int(np.mean(events.time_range()))
        for c in (1, 2, 4, 8):
            us = _timeit(lambda: tgi.get_snapshot(t, c=c))
            _row(f"fig11/snapshot_c{c}", us,
                 f"deltas={tgi.last_cost.n_deltas};bytes={tgi.last_cost.n_bytes}")


def fig12_snapshot_vs_m_r():
    """Fig 12: m (storage nodes) x r (replication)."""
    from repro.core.tgi import TGI, TGIConfig
    from repro.data.temporal_graph_gen import generate
    from repro.storage.kvstore import DeltaStore

    events = generate(N_EVENTS, seed=7)
    t = int(np.mean(events.time_range()))
    for m, r in ((1, 1), (2, 1), (2, 2), (4, 1), (4, 2)):
        cfg = TGIConfig(n_shards=4, parts_per_shard=2,
                        events_per_span=N_EVENTS // 4, eventlist_size=256)
        store = DeltaStore(m=m, r=r, backend="mem")
        from repro.core.tgi import TGI as _TGI

        tgi = _TGI.build(events, cfg, store)
        us = _timeit(lambda: tgi.get_snapshot(t, c=min(m, 4)))
        _row(f"fig12/snapshot_m{m}_r{r}", us)


def fig13b_snapshot_vs_ps():
    """Fig 13b: micro-delta partition count barely moves snapshot latency
    (micro-partitions of a delta are clustered contiguously)."""
    from repro.data.temporal_graph_gen import generate

    events = generate(N_EVENTS, seed=7)
    t = int(np.mean(events.time_range()))
    for pps in (1, 2, 4, 8):
        _, _, _, tgi = _build(parts_per_shard=pps)
        us = _timeit(lambda: tgi.get_snapshot(t))
        _row(f"fig13b/snapshot_pps{pps}", us,
             f"deltas={tgi.last_cost.n_deltas}")


def fig14_node_history():
    """Fig 14/16: node-version retrieval vs eventlist size l, parallel c,
    and partition count (smaller l / finer partitions win — the opposite
    of the snapshot trend: the paper's central trade-off)."""
    events, cfg, store, tgi0 = _build()
    t0g, t1g = events.time_range()
    t0 = int(t0g + 0.2 * (t1g - t0g))
    t1 = int(t0g + 0.9 * (t1g - t0g))
    from repro.data.temporal_graph_gen import naive_state_at

    hub = int(np.argmax(naive_state_at(events, t1).degree()))
    for l in (64, 256, 1024):
        _, _, _, tgi = _build(eventlist_size=l)
        us = _timeit(lambda: tgi.get_node_history(hub, t0, t1))
        _row(f"fig14a/nodehist_l{l}", us,
             f"deltas={tgi.last_cost.n_deltas};bytes={tgi.last_cost.n_bytes}")
    for pps in (1, 4):
        _, _, _, tgi = _build(parts_per_shard=pps)
        us = _timeit(lambda: tgi.get_node_history(hub, t0, t1))
        _row(f"fig14c/nodehist_pps{pps}", us,
             f"bytes={tgi.last_cost.n_bytes}")
    for c in (1, 4):
        us = _timeit(lambda: tgi0.get_node_history(hub, t0, t1, c=c))
        _row(f"fig14b/nodehist_c{c}", us)


def fig15a_1hop_partitioning():
    """Fig 15a: 1-hop retrieval — random vs locality vs locality+repl."""
    from repro.data.temporal_graph_gen import naive_state_at

    configs = [
        ("random", dict(partition_strategy="hash")),
        ("locality", dict(partition_strategy="locality")),
        ("locality_repl", dict(partition_strategy="locality", replicate_1hop=True)),
    ]
    for name, kw in configs:
        events, cfg, store, tgi = _build(n_events=N_EVENTS // 2, **kw)
        t = int(np.mean(events.time_range()))
        hub = int(np.argmax(naive_state_at(events, t).degree()))
        us = _timeit(lambda: tgi.get_k_hop(hub, t, 1, method="expand"))
        _row(f"fig15a/1hop_{name}", us,
             f"deltas={tgi.last_cost.n_deltas};bytes={tgi.last_cost.n_bytes}")


def fig15b_growing_data():
    """Fig 15b: snapshot latency vs total history size (~flat — timespan
    indexing isolates the touched span)."""
    for mult in (1, 2, 4):
        events, cfg, store, tgi = _build(n_events=(N_EVENTS // 2) * mult,
                                         events_per_span=N_EVENTS // 4)
        t0g, t1g = events.time_range()
        t = int(t0g + 0.4 * (t1g - t0g))
        us = _timeit(lambda: tgi.get_snapshot(t))
        _row(f"fig15b/snapshot_events{(N_EVENTS // 2) * mult}", us)


def fig15c_taf_scaling():
    """Fig 15c: analytics (max LCC) compute + SoTS fetch vs parallelism
    (through the unified HistoricalGraphStore/TemporalQuery surface)."""
    from repro.taf import HistoricalGraphStore, analytics

    events, cfg, kv, tgi = _build()
    store = HistoricalGraphStore.from_tgi(tgi)
    t0g, t1g = events.time_range()
    t0 = int(t0g + 0.4 * (t1g - t0g))
    t1 = int(t0g + 0.8 * (t1g - t0g))
    for c in (1, 2, 4):
        us = _timeit(lambda: store.subgraphs(t0, t1, c=c).execute(), repeat=2)
        _row(f"fig15c/sots_fetch_c{c}", us)
    sots = store.subgraphs(t0, t1).materialize().operand
    us = _timeit(lambda: analytics.max_lcc(sots, (t0 + t1) // 2), repeat=2)
    _row("fig15c/max_lcc", us, f"nodes={len(sots)}")


def bench_query_pushdown():
    """Beyond-paper: planner pushdown — a selective TemporalQuery prunes
    partitions/shards and projects attrs away; cost vs the full fetch."""
    from repro.taf import HistoricalGraphStore
    from repro.taf.plan import PlanExecutor

    events, cfg, kv, tgi = _build()
    store = HistoricalGraphStore.from_tgi(tgi)
    t0g, t1g = events.time_range()
    t0 = int(t0g + 0.4 * (t1g - t0g))
    t1 = int(t0g + 0.8 * (t1g - t0g))

    def run_fresh(q):
        # this bench measures the *fetch*: drop the cross-plan fetch
        # cache, snapshot LRU, and decoded-block pool so repeats
        # exercise the storage path, not the cache stack
        PlanExecutor.clear_fetch_cache()
        tgi.invalidate_caches()
        return q.run()

    full = store.nodes(t0, t1)
    us = _timeit(lambda: run_fresh(full), repeat=2)
    cost = run_fresh(full).cost
    _row("pushdown/full_fetch", us,
         f"deltas={cost.n_deltas};bytes={cost.n_bytes}")
    ids = store.snapshot(t0).node_ids()[:4]
    pruned = store.nodes(t0, t1).filter(node_ids=ids).project(attrs=False)
    us = _timeit(lambda: run_fresh(pruned), repeat=2)
    cost = run_fresh(pruned).cost
    _row("pushdown/pruned_projected", us,
         f"deltas={cost.n_deltas};bytes={cost.n_bytes}")


def bench_fetch():
    """Read-path overhaul bench: (1) decoded-block buffer pool — warm vs
    cold repeated snapshot/hierarchy reads over one span (gate: warm
    >= 2x faster); (2) range-seek vs whole-file backend — physical file
    bytes under ``projection=()`` i.e. project(attrs=False) (gate: seek
    <= 0.5x bytes); (3) accounting consistency — pool hits reported
    separately, never as physical decodes."""
    import tempfile

    from repro.core.tgi import TGI, TGIConfig
    from repro.data.temporal_graph_gen import generate
    from repro.storage.kvstore import DeltaStore

    n = N_EVENTS
    events = generate(n, seed=7)
    cfg = TGIConfig(n_shards=4, parts_per_shard=2, events_per_span=n // 4,
                    eventlist_size=256, checkpoints_per_span=4)
    t0g, t1g = events.time_range()

    # --- pool: repeated snapshot/hierarchy reads in one span ---
    with tempfile.TemporaryDirectory() as root:
        store = DeltaStore(m=4, r=1, backend="file", root=root)
        tgi = TGI.build(events, cfg, store)
        sp = tgi.spans[1].span
        ts = np.linspace(sp.t_start + 1, sp.t_end, 8).astype(np.int64)

        def read_all():
            for t in ts:
                tgi.get_snapshot(int(t))

        def cold():
            for t in ts:  # every read pays physical fetch + decode
                tgi.invalidate_caches()  # snapshot LRU AND pool
                tgi.get_snapshot(int(t))

        def warm():
            tgi.invalidate_caches(drop_pool=False)  # snapshot LRU only
            read_all()

        us_cold = _timeit(cold)
        warm()  # fill the pool outside the timed region
        us_warm = _timeit(warm)
        _row("fetch/snapshots8_cold_pool", us_cold)
        _row("fetch/snapshots8_warm_pool", us_warm,
             f"speedup={us_cold / max(us_warm, 1):.2f}x")
        tgi.invalidate_caches()
        with tgi.cost_scope() as c_cold:
            read_all()  # one shared pass: later reads pool-hit mid-pass
        tgi.invalidate_caches(drop_pool=False)
        with tgi.cost_scope() as c_warm:
            read_all()
        _row("fetch/pool_accounting", 0.0,
             f"cold_phys={c_cold.n_bytes_decompressed};"
             f"cold_pool={c_cold.n_bytes_pool};"
             f"warm_phys={c_warm.n_bytes_decompressed};"
             f"warm_pool={c_warm.n_bytes_pool};"
             f"raw_total_consistent="
             f"{c_cold.n_bytes_raw_total == c_warm.n_bytes_raw_total}")

    # --- backend: whole-file slurp vs range-seek, projected fetch ---
    t = int((t0g + t1g) // 2)
    io_bytes, us_by_mode = {}, {}
    for mode, seek in (("wholefile", False), ("rangeseek", True)):
        with tempfile.TemporaryDirectory() as root:
            store = DeltaStore(m=4, r=1, backend="file", root=root,
                               seek=seek, pool_bytes=0)
            tgi = TGI.build(events, cfg, store)
            tgi.invalidate_caches()
            store.stats.reset()
            tgi.get_snapshot(t, projection=())  # attrs tiles skipped
            io_bytes[mode] = store.stats.bytes_io

            def snap():
                tgi.invalidate_caches()
                tgi.get_snapshot(t, projection=())

            us_by_mode[mode] = _timeit(snap)
            _row(f"fetch/{mode}_projected_snapshot", us_by_mode[mode],
                 f"bytes_io={io_bytes[mode]}")
    _row("fetch/rangeseek_vs_wholefile", 0.0,
         f"io_ratio={io_bytes['rangeseek'] / max(io_bytes['wholefile'], 1):.3f};"
         f"latency_ratio={us_by_mode['rangeseek'] / max(us_by_mode['wholefile'], 1):.2f}")


def bench_service():
    """Service plane bench: a real local cluster (3 storage cells x
    r=2, separate OS processes) serving the wire protocol.  Measures
    (1) ingest over the wire (seq-stamped replicated puts), (2) server-
    measured bytes_io of projected vs full remote reads (projection
    pushdown survives the network hop), (3) concurrent client sessions
    x concurrent queries with every cell up, (4) the same workload with
    one replica SIGKILLed mid-bench — gate (asserted): zero failed
    queries (timeout/retry + replica failover + hedged batches absorb
    the crash), and (5) replica restart: change-feed catch-up records
    and convergence — gate (asserted): the restarted cell again holds
    every key it owns."""
    import tempfile
    import threading

    from repro.service import ClusterSpec, LocalCluster
    from repro.storage.kvstore import DeltaKey

    n_keys = max(24, int(96 * SCALE))
    n_sessions = 4
    n_queries = max(4, int(12 * SCALE))  # per session per phase
    rng = np.random.RandomState(7)
    with tempfile.TemporaryDirectory() as root:
        spec = ClusterSpec(n_cells=3, r=2, backend="file", root=root)
        with LocalCluster(spec, mode="subprocess") as cl:
            store = cl.client(timeout=3.0, retries=1, backoff=0.02,
                              suspect_ttl=5.0)
            keys = [DeltaKey(t, s, "E:0", p)
                    for t in range(max(4, n_keys // 6))
                    for s in range(3) for p in range(2)][:n_keys]
            payloads = {
                k: {"t": np.arange(400, dtype=np.int64) * (k.tsid + 1),
                    "v": rng.randn(400).astype(np.float32)}
                for k in keys
            }
            t0 = time.perf_counter()
            for k in keys:
                store.put(k, payloads[k])
            dt = time.perf_counter() - t0
            _row("service/ingest_put", dt / len(keys) * 1e6,
                 f"eps={len(keys) / dt:.0f};cells=3;r=2")

            # --- projection pushdown, measured on the SERVERS ---
            def server_io():
                return sum(store.cell_status(i)["stats"]["bytes_io"]
                           for i in range(3))

            # dedicated wide blocks: the projected column is a sliver of
            # the blob, so the seek-backend saving is visible (blocks
            # smaller than the 4 KiB directory-prefix pread are served
            # whole either way)
            probe = [DeltaKey(90 + i, i % 3, "S:0:0", 0) for i in range(4)]
            for k in probe:
                store.put(k, {"t": np.arange(256, dtype=np.int64),
                              "v": rng.randn(60_000).astype(np.float32)})
            store.clear_pool()
            base = server_io()
            for k in probe:
                store.get(k, fields=["t"])
            proj_io = server_io() - base
            store.clear_pool()
            base = server_io()
            for k in probe:
                store.get(k)
            full_io = server_io() - base
            _row("service/projection_pushdown", 0.0,
                 f"server_io_projected={proj_io};server_io_full={full_io};"
                 f"ratio={proj_io / max(full_io, 1):.3f}")

            # --- client sessions x concurrent queries ---
            def run_sessions(tag):
                clients = [cl.client(timeout=3.0, retries=1, backoff=0.02,
                                     suspect_ttl=5.0)
                           for _ in range(n_sessions)]
                failed = [0]
                done = [0]

                def session(si):
                    srng = np.random.RandomState(100 + si)
                    client = clients[si]
                    for _ in range(n_queries):
                        sub = [keys[i] for i in
                               srng.choice(len(keys), size=8, replace=False)]
                        try:
                            out = client.multiget(sub, c=2, fields=["t"])
                            assert len(out) == len(sub)
                        except Exception:
                            failed[0] += 1
                        done[0] += 1

                threads = [threading.Thread(target=session, args=(i,))
                           for i in range(n_sessions)]
                t0 = time.perf_counter()
                for t in threads:
                    t.start()
                mid_kill = tag == "replica_killed"
                if mid_kill:
                    time.sleep(0.02)  # let queries start, then crash a cell
                    cl.kill(0)
                for t in threads:
                    t.join()
                dt = time.perf_counter() - t0
                nq = n_sessions * n_queries
                stats = [c.stats for c in clients]
                derived = (f"qps={nq / dt:.0f};failed={failed[0]};"
                           f"failovers={sum(s.failovers for s in stats)};"
                           f"hedged={sum(s.hedged_reads for s in stats)}")
                for c in clients:
                    c.close()
                _row(f"service/queries_{tag}", dt / nq * 1e6, derived)
                return failed[0]

            run_sessions("all_up")
            failed = run_sessions("replica_killed")
            # the resilience gate the CI smoke step runs this bench for:
            # a SIGKILLed replica must cost ZERO failed queries
            assert failed == 0, \
                f"service bench: {failed} queries failed during replica kill"

            # --- writes the dead cell misses, then restart + catch-up ---
            extra = [DeltaKey(50 + i, i % 3, "E:1", 0)
                     for i in range(max(6, n_keys // 4))]
            for k in extra:
                store.put(k, {"x": np.arange(64, dtype=np.int64)})
            t0 = time.perf_counter()
            cl.restart(0)
            dt = time.perf_counter() - t0
            all_keys = keys + probe + extra
            owned = sum(1 for k in all_keys if 0 in store.replicas(k))
            status = store.cell_status(0)
            converged = status["n_keys"] == owned
            _row("service/replica_catchup", dt * 1e6,
                 f"owned_keys={owned};recovered_keys={status['n_keys']};"
                 f"converged={converged};"
                 f"killed_phase_failed={failed}")
            # second gate: the restarted replica must hold every key it
            # owns again (feed catch-up actually converged)
            assert converged, \
                f"service bench: catch-up left {owned - status['n_keys']} " \
                f"of {owned} owned keys missing on the restarted cell"
            store.close()


def bench_transport():
    """Pipelined wire transport bench: the same cluster and the same 8
    concurrent 64-key sessions, three transports.  (1) serial_get_chain
    — the pre-pipelining shape: one blocking get() per key on a
    checked-out connection (pipeline=False, a socket per in-flight
    request).  (2) grouped_frames — PR 6's one-MULTIGET-per-group batch
    through the same checkout pool, still one request in flight per
    connection.  (3) pipelined_multiget — the multiplexer: all 8
    sessions share ONE client, so each cell sees a single socket
    carrying 8 interleaved CHUNK streams (out-of-order completion,
    replica-parallel fan-out).  The clients run with the decoded-block
    pool off and the cluster is warmed first, so the phases compare
    pure transport: same server reads, same decodes, different wire
    discipline.  Gate (asserted at full scale): pipelined throughput
    >= 3x the serial chain.  Then the chaos phases: SIGKILL
    mid-pipeline — gate: zero failed queries; overwrite churn — gate:
    ack-watermark truncation observed and the feeds stay bounded;
    restart — gate: catch-up converges past the truncated feeds."""
    import tempfile
    import threading

    from repro.service import ClusterSpec, LocalCluster
    from repro.storage.kvstore import DeltaKey

    n_sessions = 8
    batch = 64
    rounds = max(1, int(round(2 * SCALE)))
    rng = np.random.RandomState(11)
    with tempfile.TemporaryDirectory() as root:
        spec = ClusterSpec(n_cells=3, r=2, backend="file", root=root,
                           feed_keep=32)
        with LocalCluster(spec, mode="subprocess") as cl:
            store = cl.client(timeout=5.0, retries=1, backoff=0.02,
                              suspect_ttl=5.0)
            # one disjoint 64-key slice per session, spread over every
            # placement so each multiget fans out to all three cells
            keys = [DeltaKey(t, s, "E:0", p)
                    for t in range(max(6, -(-(n_sessions * batch) // 6)))
                    for s in range(3) for p in range(2)][: n_sessions * batch]
            for k in keys:
                store.put(k, {"t": np.arange(64, dtype=np.int64) * (k.tsid + 1),
                              "v": rng.randn(64).astype(np.float32)})
            slices = [keys[i * batch:(i + 1) * batch]
                      for i in range(n_sessions)]

            def run_sessions(one_session):
                def fn():
                    threads = [threading.Thread(target=one_session, args=(i,))
                               for i in range(n_sessions)]
                    for t in threads:
                        t.start()
                    for t in threads:
                        t.join()
                return fn

            # (1) serial chain: one blocking round-trip per key, shared
            # checkout pool (grows to one socket per concurrent request)
            serial_store = cl.client(timeout=10.0, pipeline=False,
                                     pool_bytes=0)
            for k in keys:  # warm cells (serve cache, extents, handles)
                serial_store.get(k, fields=["t"])

            def chain(si):
                for _ in range(rounds):
                    for k in slices[si]:
                        serial_store.get(k, fields=["t"])

            us_chain = _timeit(run_sessions(chain), repeat=1)
            per_key = n_sessions * batch * rounds
            _row("transport/serial_get_chain", us_chain / per_key,
                 f"sessions={n_sessions};batch={batch};rounds={rounds};"
                 f"total_ms={us_chain / 1e3:.1f}")

            # (2) grouped frames, still serial per connection (PR 6)
            def grouped(si):
                for _ in range(rounds):
                    serial_store.multiget(slices[si], fields=["t"])

            us_grouped = _timeit(run_sessions(grouped), repeat=1)
            _row("transport/grouped_frames", us_grouped / per_key,
                 f"total_ms={us_grouped / 1e3:.1f};"
                 f"vs_chain={us_chain / max(us_grouped, 1e-9):.2f}x")
            serial_store.close()

            # (3) the multiplexer: 8 sessions, one shared client, one
            # socket per cell carrying every interleaved stream
            pipe_store = cl.client(timeout=10.0, pool_bytes=0, window=64)

            def pipelined(si):
                for _ in range(rounds):
                    pipe_store.multiget(slices[si], fields=["t"])

            us_pipe = _timeit(run_sessions(pipelined), repeat=1)
            speedup = us_chain / max(us_pipe, 1e-9)
            _row("transport/pipelined_multiget", us_pipe / per_key,
                 f"total_ms={us_pipe / 1e3:.1f};vs_chain={speedup:.2f}x;"
                 f"vs_grouped={us_grouped / max(us_pipe, 1e-9):.2f}x")
            ts = pipe_store.transport_stats()
            hwm = ts["inflight_hwm"]
            _row("transport/mux_depth", 0.0,
                 f"inflight_hwm={hwm};"
                 f"pipelined_rts={ts['rt_pipelined']};"
                 f"serial_rts={ts['rt_serial']};"
                 f"reconnects={ts['rt_reconnects']}")
            assert hwm > 1, "transport bench never actually pipelined"
            assert ts["rt_pipelined"] > 0, \
                "transport bench: no request ever rode the pipeline"
            # the headline gate: pipelining must beat the synchronous
            # round-trip chain by >= 3x at full scale
            if SCALE >= 1.0:
                assert speedup >= 3.0, \
                    f"transport bench: pipelined multiget only " \
                    f"{speedup:.2f}x over the serial chain (gate: 3x)"
            _row("transport/speedup_gate", 0.0,
                 f"speedup={speedup:.2f}x;gate=3x;"
                 f"asserted={1 if SCALE >= 1.0 else 0}")

            # --- SIGKILL mid-pipeline: every future must drain ---
            failed = [0]

            def chaos(si):
                try:
                    for _ in range(3):
                        out = pipe_store.multiget(slices[si], fields=["t"])
                        assert len(out) == batch
                except Exception:
                    failed[0] += 1

            threads = [threading.Thread(target=chaos, args=(i,))
                       for i in range(n_sessions)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            time.sleep(0.02)
            cl.kill(0)  # SIGKILL while multigets are in flight
            for t in threads:
                t.join()
            dt = time.perf_counter() - t0
            failovers = pipe_store.stats.failovers
            _row("transport/sigkill_mid_pipeline", dt * 1e6,
                 f"failed={failed[0]};failovers={failovers};sessions=8")
            assert failed[0] == 0, \
                f"transport bench: {failed[0]} sessions failed during kill"
            pipe_store.close()
            cl.restart(0)

            # --- overwrite churn: watermark-driven feed truncation ---
            store._suspects.clear()
            for _churn in range(2):
                for k in keys:
                    store.put(k, {"t": np.arange(64, dtype=np.int64),
                                  "v": rng.randn(64).astype(np.float32)})
            feeds = store.feed_status()
            truncations = sum(f["truncations"] for f in feeds if f)
            max_len = max(f["len"] for f in feeds if f)
            max_bytes = max(f["bytes"] for f in feeds if f)
            records_written = len(keys) * 3  # initial fill + 2 churn rounds
            _row("transport/feed_truncation", 0.0,
                 f"truncations={truncations};max_feed_len={max_len};"
                 f"max_feed_bytes={max_bytes};"
                 f"records_per_cell>={records_written * 2 // 3}")
            # gates: truncation actually ran, and the feeds stayed far
            # below the record count a full history would hold
            assert truncations >= 1, "no feed truncation under churn"
            assert max_len < records_written, \
                f"feed unbounded: {max_len} records retained"

            # --- restart past truncated feeds: catch-up still converges ---
            cl.kill(1)
            for k in keys[: len(keys) // 2]:  # records cell 1 misses
                store.put(k, {"t": np.arange(64, dtype=np.int64),
                              "v": rng.randn(64).astype(np.float32)})
            t0 = time.perf_counter()
            cl.restart(1)
            dt = time.perf_counter() - t0
            owned = sum(1 for k in set(keys) if 1 in store.replicas(k))
            status = store.cell_status(1)
            converged = status["n_keys"] == owned
            _row("transport/truncated_restart_catchup", dt * 1e6,
                 f"owned_keys={owned};recovered_keys={status['n_keys']};"
                 f"converged={converged};floor={status['feed']['floor']}")
            assert converged, \
                f"catch-up past truncation left " \
                f"{owned - status['n_keys']} keys missing"
            store.close()


def bench_multiwriter():
    """Multi-writer chaos bench: three lease-fenced writer PROCESSES
    hammer one subprocess cluster under distinct ``(epoch, seq)``
    lanes; one writer is SIGKILLed mid-storm (no release, no goodbye).
    Gates (always asserted — these are correctness, not speed):
    (1) zero acked writes lost — every key serves its max-vseq winner
    across the union of the writers' acked-op logs (modulo the dead
    writer's single possibly-in-flight next op, reconstructed from its
    seed); (2) lease expiry triggers orphan-seq reconciliation within
    one sweep — the dead lane seals at one agreed point >= its acked
    high-water mark on every cell and the ack watermark advances past
    it, resuming feed truncation; (3) after a canonical vacuum both
    replicas of every placement hold byte-identical chunk/extent
    files, regardless of per-cell arrival interleaving."""
    import hashlib
    import subprocess
    import sys
    import tempfile
    from pathlib import Path

    from repro.service import ClusterSpec, LocalCluster
    from repro.service.stress import (key_for, payload_arrays,
                                      read_acked_log)
    from repro.storage.kvstore import KeyMissing, make_vseq, split_vseq

    n_ops = max(80, int(round(160 * SCALE)))  # per surviving writer
    kill_at = 30  # acked ops before the victim is SIGKILLed
    keyspace = 24
    lease_ttl = 1.0
    seeds = (21, 22, 23)  # seeds[0] is the victim

    def matches(got, token):
        want = payload_arrays(token)
        return (set(got) == set(want)
                and all(np.array_equal(got[f], want[f]) for f in want))

    def spawn(cl, seed, out, n_writes):
        import repro
        src = str(Path(next(iter(repro.__path__))).parent)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [src] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
                     if p])
        cmd = [sys.executable, "-m", "repro.service.stress",
               "--addrs", ",".join(f"{h}:{p}" for h, p in cl.addrs),
               "--r", str(cl.spec.r), "--n-writes", str(n_writes),
               "--keyspace", str(keyspace), "--seed", str(seed),
               "--out", str(out), "--lease-ttl", str(lease_ttl)]
        proc = subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                                stderr=subprocess.DEVNULL, text=True)
        line = proc.stdout.readline()
        assert line.startswith("WRITER READY"), line
        return proc

    with tempfile.TemporaryDirectory() as root:
        spec = ClusterSpec(n_cells=3, r=2, backend="file", root=root,
                           feed_keep=16, lease_ttl=lease_ttl)
        with LocalCluster(spec, mode="subprocess") as cl:
            logs = [Path(root) / f"writer{i}.log" for i in range(3)]
            t0 = time.perf_counter()
            procs = [spawn(cl, seeds[i], logs[i],
                           10**6 if i == 0 else n_ops)
                     for i in range(3)]
            # SIGKILL the victim once it has >= kill_at acked ops
            deadline = time.monotonic() + 120
            while time.monotonic() < deadline:
                if (logs[0].exists()
                        and len(logs[0].read_text().splitlines())
                        >= kill_at):
                    break
                time.sleep(0.02)
            procs[0].kill()
            t_kill = time.perf_counter()
            procs[0].wait(timeout=10)
            for p in procs[1:]:  # survivors run their storm to the end
                assert p.wait(timeout=600) == 0, \
                    "multiwriter bench: a surviving writer degraded"
            t_storm = time.perf_counter() - t0

            rows = [read_acked_log(log) for log in logs]
            dead = rows[0]
            assert len(dead) >= kill_at
            epoch = split_vseq(max(v for _, _, v, _ in dead))[0]
            max_acked = max(split_vseq(v)[1] for _, _, v, _ in dead)
            acked_total = sum(len(r) for r in rows)
            _row("multiwriter/storm", t_storm * 1e6 / acked_total,
                 f"writers=3;killed=1;acked_total={acked_total};"
                 f"dead_acked={len(dead)};survivor_ops={n_ops}x2")

            reader = cl.client(timeout=5.0, retries=1, backoff=0.02,
                               pool_bytes=0)
            # (2) lease expiry -> orphan-seq reconciliation seals the
            # dead lane at ONE agreed point on every cell
            deadline = time.monotonic() + 120
            while time.monotonic() < deadline:
                lanes = [(st or {}).get("lanes", {}).get(str(epoch))
                         for st in reader.feed_status()]
                lanes = [l for l in lanes if l]
                if len(lanes) == 3 and all(l["seal"] is not None
                                           for l in lanes):
                    break
                time.sleep(0.1)
            else:
                raise AssertionError(
                    "multiwriter bench: dead lane never sealed")
            t_seal = time.perf_counter() - t_kill
            seals = {l["seal"] for l in lanes}
            assert len(seals) == 1, f"split-brain seal: {seals}"
            seal = seals.pop()
            assert seal >= max_acked, \
                f"seal {seal} below acked high-water {max_acked}"
            _row("multiwriter/reconcile_latency", t_seal * 1e6,
                 f"seal={seal};acked_hwm={max_acked};"
                 f"lease_ttl={lease_ttl}")

            # ack watermark past the dead lane; feed truncation resumed
            reader.quiesce(truncate=True)
            water_ok = 0
            for st in reader.feed_status():
                assert st is not None
                lane = st["lanes"][str(epoch)]
                assert lane["floor"] == lane["seal"] and not lane["lease"]
                assert st["ack_water"] >= make_vseq(epoch, max_acked)
                water_ok += 1
            _row("multiwriter/ack_watermark_resume", 0.0,
                 f"cells={water_ok};floor=seal;dead_epoch={epoch}")

            # (1) zero acked writes lost: per-key max-vseq winner over
            # the union of the logs, modulo the victim's one possibly
            # in-flight op (applied by the cluster, never logged)
            n_acked = len(dead)
            rng = np.random.default_rng(seeds[0])
            slots = [int(rng.integers(0, keyspace))
                     for _ in range(n_acked + 1)]
            cand_key = key_for(slots[n_acked])
            cand_op = "DEL" if n_acked % 10 == 9 else "PUT"
            cand_token = seeds[0] * 1_000_003 + n_acked
            cand_vseq = make_vseq(epoch, max_acked + 1)
            winners = {}
            for wrows in rows:
                for op, key, vseq, token in wrows:
                    if key not in winners or vseq > winners[key][1]:
                        winners[key] = (op, vseq, token)
            lost = []
            for key, (op, vseq, token) in winners.items():
                cand = key == cand_key and cand_vseq > vseq
                try:
                    got = reader.get(key)
                except KeyMissing:
                    if not (op == "DEL" or (cand and cand_op == "DEL")):
                        lost.append(key)
                    continue
                ok = op == "PUT" and matches(got, token)
                if cand and cand_op == "PUT":
                    ok = ok or matches(got, cand_token)
                if not ok:
                    lost.append(key)
            _row("multiwriter/zero_acked_lost", 0.0,
                 f"keys_checked={len(winners)};lost={len(lost)}")
            assert not lost, f"acked writes lost on keys: {lost}"

            # (3) canonical vacuum -> replica files byte-identical per
            # placement (each chunk/extent lives on exactly r=2 cells
            # under the same relative path)
            t0 = time.perf_counter()
            for node in range(3):
                for _ in range(50):  # background maint may hold the slot
                    if reader.maintain(node, canonical=True):
                        break
                    time.sleep(0.1)
                else:
                    raise AssertionError(
                        f"canonical vacuum never ran on cell {node}")
            us_canon = (time.perf_counter() - t0) * 1e6
            by_path = {}
            for node in range(3):
                croot = Path(spec.cell_root(node))
                for p in sorted(croot.rglob("*")):
                    if p.is_file() and p.suffix in (".tgi", ".tgx"):
                        h = hashlib.sha256(p.read_bytes()).hexdigest()
                        by_path.setdefault(
                            str(p.relative_to(croot)), []).append(h)
            assert by_path, "multiwriter bench: no chunk files found"
            mismatched = [rel for rel, hs in by_path.items()
                          if len(set(hs)) != 1]
            lonely = [rel for rel, hs in by_path.items() if len(hs) < 2]
            _row("multiwriter/replica_byte_identity", us_canon,
                 f"files={len(by_path)};mismatched={len(mismatched)};"
                 f"unreplicated={len(lonely)}")
            assert not mismatched, \
                f"replica divergence after canonical vacuum: {mismatched}"
            assert not lonely, f"under-replicated chunks: {lonely}"
            reader.close()


def fig17_incremental_vs_temporal():
    """Fig 17: NodeComputeDelta vs NodeComputeTemporal cumulative time vs
    number of evaluated versions."""
    from repro.taf import HistoricalGraphStore, analytics

    events, cfg, kv, tgi = _build(n_events=N_EVENTS // 2)
    store = HistoricalGraphStore.from_tgi(tgi)
    t0g, t1g = events.time_range()
    sots = (store.subgraphs(int(t0g + 0.3 * (t1g - t0g)), int(t1g))
            .materialize().operand)
    pts_all = sots.change_points()
    for n_versions in (8, 32, 128):
        pts = pts_all[:: max(len(pts_all) // n_versions, 1)][:n_versions]
        us_t = _timeit(lambda: analytics.degree_series_temporal(sots, pts), repeat=1)
        us_d = _timeit(lambda: analytics.degree_series_delta(sots, pts), repeat=1)
        _row(f"fig17/temporal_v{n_versions}", us_t)
        _row(f"fig17/delta_v{n_versions}", us_d,
             f"speedup={us_t / max(us_d, 1):.2f}x")


def bench_replay():
    """Replay micro-bench: per-timepoint ``_state_at`` rescans vs the
    one-pass ``state_at_many`` batch at T in {1, 8, 64} — the tentpole
    speedup of the batched replay engine (Kairos-style shared pass)."""
    from repro.taf import HistoricalGraphStore, operators as ops, replay

    events, cfg, kv, tgi = _build(n_events=N_EVENTS // 2)
    store = HistoricalGraphStore.from_tgi(tgi)
    t0g, t1g = events.time_range()
    sots = (store.subgraphs(int(t0g + 0.3 * (t1g - t0g)), int(t1g))
            .materialize().operand)
    pts_all = sots.change_points()
    for T in (1, 8, 64):
        pts = pts_all[:: max(len(pts_all) // T, 1)][:T].astype(np.int64)

        def per_t():
            for t in pts:
                ops._state_at(sots, int(t))

        us_loop = _timeit(per_t)
        us_batch = _timeit(lambda: replay.state_at_many(sots, pts))
        _row(f"replay/state_loop_T{len(pts)}", us_loop)
        _row(f"replay/state_batch_T{len(pts)}", us_batch,
             f"speedup={us_loop / max(us_batch, 1):.2f}x")
    # edge side: neighbor-set loops vs the shared pair table
    pts = pts_all[:: max(len(pts_all) // 16, 1)][:16].astype(np.int64)

    def nbr_loop():
        for t in pts:
            for i in range(len(sots)):
                ops._neighbors_at_ref(sots, i, int(t))

    us_loop = _timeit(nbr_loop, repeat=1)
    us_batch = _timeit(lambda: replay.edge_replay(sots).degree_series(pts),
                       repeat=1)
    _row("replay/neighbors_loop_T16", us_loop)
    _row("replay/degree_series_T16", us_batch,
         f"speedup={us_loop / max(us_batch, 1):.2f}x")


def bench_batched_snapshots():
    """Batched Algorithm 1: T independent get_snapshot calls vs one
    get_snapshots sharing hierarchy-path + eventlist fetches."""
    events, cfg, store, tgi = _build(n_events=N_EVENTS // 2)
    t0g, t1g = events.time_range()
    for T in (4, 16):
        ts = np.linspace(t0g + 0.1 * (t1g - t0g), t1g, T).astype(np.int64)

        def singles():
            for t in ts:
                tgi.invalidate_caches()
                tgi.get_snapshot(int(t))

        def batch():
            tgi.invalidate_caches()
            tgi.get_snapshots([int(t) for t in ts])

        us_s = _timeit(singles, repeat=2)
        us_b = _timeit(batch, repeat=2)
        _row(f"snapshots/singles_T{T}", us_s)
        _row(f"snapshots/batched_T{T}", us_b,
             f"speedup={us_s / max(us_b, 1):.2f}x")


def bench_storage():
    """Storage format (paper Fig. 10 / §6 'compactly stores'): TGI1 raw
    vs TGI2 compressed-columnar blocks on the same default workload —
    bytes per index component, snapshot retrieval, and a 16-point
    timeslice scan.  The acceptance gate for the format: TGI2 total
    bytes <= 0.6x TGI1 with snapshot latency within 1.2x."""
    from repro.core.tgi import TGI, TGIConfig
    from repro.data.temporal_graph_gen import generate
    from repro.storage.kvstore import DeltaStore
    from repro.taf import HistoricalGraphStore

    events = generate(N_EVENTS, seed=7)
    cfg = TGIConfig(n_shards=4, parts_per_shard=2, events_per_span=N_EVENTS // 4,
                    eventlist_size=256, checkpoints_per_span=4)
    t0g, t1g = events.time_range()
    t = int((t0g + t1g) // 2)
    ts = np.linspace(t0g + 0.1 * (t1g - t0g), t1g, 16).astype(np.int64)
    fmts = ("TGI1", "TGI2")
    tgis, totals = {}, {}
    for fmt in fmts:
        kv = DeltaStore(m=4, r=1, backend="mem", fmt=fmt)
        tgis[fmt] = TGI.build(events, cfg, kv)
        rep = tgis[fmt].storage_report()
        totals[fmt] = rep["totals"]
        for comp, row in rep["components"].items():
            _row(f"storage/{fmt}/bytes_{comp}", 0.0,
                 f"raw={row['raw']};encoded={row['encoded']};count={row['count']}")
        _row(f"storage/{fmt}/bytes_total", 0.0,
             f"raw={rep['totals']['raw']};encoded={rep['totals']['encoded']};"
             f"ratio={rep['totals']['ratio']:.3f}")

    # latency: the two formats are timed in alternating rounds so clock
    # drift (CPU steal in shared containers) hits both equally
    def snap(tgi):
        tgi.invalidate_caches()
        tgi.get_snapshot(t)

    rounds = (REPEAT_OVERRIDE if REPEAT_OVERRIDE is not None else 8) * 5
    for f in fmts:  # warm caches/code paths outside the timed region
        snap(tgis[f])
    samples_snap = {f: [] for f in fmts}
    samples_slice = {f: [] for f in fmts}
    queries = {
        f: HistoricalGraphStore.from_tgi(tgis[f])
        .nodes(int(t0g + 0.1 * (t1g - t0g)), int(t1g)).timeslice(ts)
        for f in fmts
    }
    for r in range(rounds):
        order = fmts if r % 2 == 0 else fmts[::-1]  # no fixed-order bias
        for f in order:
            t0 = time.perf_counter()
            snap(tgis[f])
            samples_snap[f].append(time.perf_counter() - t0)
    for f in fmts:
        queries[f].execute()  # warm
    for r in range(rounds):
        order = fmts if r % 2 == 0 else fmts[::-1]
        for f in order:
            tgis[f].invalidate_caches()
            t0 = time.perf_counter()
            queries[f].execute()
            samples_slice[f].append(time.perf_counter() - t0)
    for f in fmts:
        snap(tgis[f])  # re-run once so last_cost reflects the snapshot
        _row(f"storage/{f}/snapshot", min(samples_snap[f]) * 1e6,
             f"enc_bytes={tgis[f].last_cost.n_bytes};"
             f"raw_bytes={tgis[f].last_cost.n_bytes_decompressed}")
        _row(f"storage/{f}/timeslice_T16", min(samples_slice[f]) * 1e6)
    # latency ratio = median of per-round paired ratios: each pair runs
    # back-to-back, so shared-machine clock drift cancels out of it
    lat_ratio = float(np.median(
        np.asarray(samples_snap["TGI2"]) / np.asarray(samples_snap["TGI1"])))
    _row("storage/TGI2_vs_TGI1", 0.0,
         f"bytes_ratio={totals['TGI2']['encoded'] / totals['TGI1']['encoded']:.3f};"
         f"snapshot_latency_ratio={lat_ratio:.2f}")


def bench_ingest():
    """Streaming ingest + compaction (§4.4 / ROADMAP): sustained
    events/sec across micro update batches, the per-batch latency curve
    (incremental version-chain append keeps it flat in batch size, not
    total history size — measured on a steady-state churn workload so
    graph growth doesn't mask the history term), and span compaction
    (micro-span merge ratio, store GC byte consistency)."""
    from repro.core.tgi import TGI, TGIConfig
    from repro.data.temporal_graph_gen import generate
    from repro.storage.kvstore import DeltaStore

    n = N_EVENTS
    events = generate(n, n_nodes_hint=max(n // 40, 64), seed=7)
    cfg = TGIConfig(n_shards=4, parts_per_shard=2, events_per_span=n // 4,
                    eventlist_size=256, checkpoints_per_span=4)
    batch = max(n // 40, 1)  # micro-batches: 1/10th of a span

    # --- per-batch update latency curve (incremental VC append) ---
    store = DeltaStore(m=4, r=1, backend="mem")
    tgi = TGI.build(events.take(slice(0, batch)), cfg, store)
    lat = []
    t0_all = time.perf_counter()
    for lo in range(batch, n, batch):
        t0 = time.perf_counter()
        tgi.update(events.take(slice(lo, min(lo + batch, n))))
        lat.append(time.perf_counter() - t0)
    total_s = time.perf_counter() - t0_all
    q = max(len(lat) // 4, 1)
    early = float(np.median(lat[:q])) * 1e6
    late = float(np.median(lat[-q:])) * 1e6
    _row("ingest/update_batch_early", early, f"batch={batch}")
    _row("ingest/update_batch_late", late,
         f"late_over_early={late / max(early, 1):.2f}x")
    _row("ingest/update_events_per_sec", 0.0,
         f"eps={int((n - batch) / max(total_s, 1e-9))}")

    # --- streamed append (buffered; spans sealed on threshold) ---
    store2 = DeltaStore(m=4, r=1, backend="mem")
    tgi2 = TGI.build(events.take(slice(0, batch)), cfg, store2)
    t0 = time.perf_counter()
    for lo in range(batch, n, batch):
        tgi2.append(events.take(slice(lo, min(lo + batch, n))))
    tgi2.flush()
    append_s = time.perf_counter() - t0
    _row("ingest/append_events_per_sec", 0.0,
         f"eps={int((n - batch) / max(append_s, 1e-9))}")

    # --- compaction: span merge + store GC ---
    spans_before = len(tgi.spans)
    live_before = tgi.index_size_bytes()
    t0 = time.perf_counter()
    stats = tgi.compact()
    us = (time.perf_counter() - t0) * 1e6
    _row("ingest/compact", us,
         f"spans={spans_before}->{stats.spans_after};"
         f"reduction={stats.span_reduction:.1f}x;"
         f"keys_deleted={stats.keys_deleted}")
    rep = tgi.storage_report()["totals"]
    _row("ingest/compact_storage", 0.0,
         f"live_bytes={live_before}->{tgi.index_size_bytes()};"
         f"report_consistent={tgi.index_size_bytes() == rep['encoded']}")

    # --- read path after the whole pipeline ---
    t = int(np.mean(events.time_range()))

    def snap():
        tgi.invalidate_caches()
        tgi.get_snapshot(t)

    _row("ingest/snapshot_after_compact", _timeit(snap))


def table1_index_comparison():
    """Table 1: measured fetch cost (deltas, cardinality, bytes) and index
    size for Log, DeltaGraph (monolithic), and TGI on the same history."""
    from repro.data.temporal_graph_gen import naive_state_at

    n = N_EVENTS // 2
    variants = [
        ("log", dict(events_per_span=10**9, checkpoints_per_span=1,
                     n_shards=1, parts_per_shard=1, eventlist_size=256)),
        ("deltagraph", dict(events_per_span=n // 4, checkpoints_per_span=4,
                            n_shards=1, parts_per_shard=1, eventlist_size=256)),
        ("tgi", dict(events_per_span=n // 4, checkpoints_per_span=4,
                     n_shards=4, parts_per_shard=2, eventlist_size=256)),
    ]
    for name, kw in variants:
        events, cfg, store, tgi = _build(n_events=n, **kw)
        t0g, t1g = events.time_range()
        t = int(t0g + 0.7 * (t1g - t0g))
        hub = int(np.argmax(naive_state_at(events, t).degree()))
        us = _timeit(lambda: tgi.get_snapshot(t))
        _row(f"table1/{name}/snapshot", us,
             f"deltas={tgi.last_cost.n_deltas};card={tgi.last_cost.sum_cardinality}")
        us = _timeit(lambda: tgi.get_node_history(hub, int(t0g + 0.3 * (t1g - t0g)), t))
        _row(f"table1/{name}/node_versions", us,
             f"deltas={tgi.last_cost.n_deltas};bytes={tgi.last_cost.n_bytes}")
        us = _timeit(lambda: tgi.get_k_hop(hub, t, 1))
        _row(f"table1/{name}/1hop", us,
             f"deltas={tgi.last_cost.n_deltas}")
        _row(f"table1/{name}/index_size", 0.0,
             f"bytes={store.stats.bytes_written}")


def bench_checkpoint_store():
    """Beyond-paper: TGI checkpoint store — delta-vs-snapshot bytes and
    restore latency vs parallel fetch (the LM-plane integration)."""
    import jax

    from repro.storage.checkpoint import CheckpointConfig, CheckpointStore
    from repro.storage.kvstore import DeltaStore

    rng = np.random.RandomState(0)
    tree = {"w": rng.randn(512, 1024).astype(np.float32),
            "m": rng.randn(512, 1024).astype(np.float32)}
    store = CheckpointStore(DeltaStore(m=4, r=2, backend="mem"),
                            CheckpointConfig(snapshot_every=4))
    b_prev = 0
    for s in range(8):
        tree = jax.tree.map(
            lambda x: x + rng.randn(*x.shape).astype(np.float32) * 1e-3, tree
        )
        store.save(s, tree)
        b = store.store.stats.bytes_written
        _row(f"ckpt/save{s}_{store.saves[-1]['kind']}", 0.0, f"bytes={b - b_prev}")
        b_prev = b
    for c in (1, 4):
        us = _timeit(lambda: store.restore(step=7, c=c), repeat=2)
        _row(f"ckpt/restore_c{c}", us)


def bench_delta_overlay_kernel():
    """Kernel micro-bench: fused overlay (jit'd jnp mirror of the Pallas
    kernel) vs the numpy pairwise chain, h=2..8 (DESIGN §7 HBM argument)."""
    import jax
    import jax.numpy as jnp

    from repro.core.delta import Delta, delta_sum
    from repro.kernels.delta_overlay import ref as ov_ref

    P, S, K = 8, 2048, 4
    rng = np.random.RandomState(0)
    for h in (2, 4, 8):
        valid = rng.rand(h, P, S) < 0.3
        present = (rng.rand(h, P, S) < 0.8).astype(np.int8)
        attrs = rng.randint(-1, 5, size=(h, P, S, K)).astype(np.int32)
        fold = jax.jit(ov_ref.overlay_ref)
        jax.block_until_ready(fold(jnp.asarray(valid), jnp.asarray(present),
                                   jnp.asarray(attrs)))  # warm
        us_k = _timeit(lambda: jax.block_until_ready(
            fold(jnp.asarray(valid), jnp.asarray(present), jnp.asarray(attrs))))
        ds = []
        for i in range(h):
            d = Delta.empty(P, S, K)
            d.valid, d.present, d.attrs = valid[i], present[i], attrs[i]
            ds.append(d)

        def chain():
            acc = ds[0]
            for d in ds[1:]:
                acc = delta_sum(acc, d)

        us_c = _timeit(chain)
        _row(f"kernel/overlay_fused_h{h}", us_k, f"chain_us={us_c:.0f}")


def bench_fusion():
    """Whole-plan compilation (repro.taf.compile): one fused device
    dispatch vs the staged host executor for T-point temporal analytics,
    T in {8, 32, 128}.  Both sides are warmed first, so compile/trace
    time is excluded and the fused numbers are pure dispatch+execute;
    the compile-cache hit rate over the timed runs is reported and the
    timed runs are asserted re-trace-free.  Gate (asserted at full
    scale; smoke runs report only): fused >= 3x faster than staged for
    the T=128 connected-components query, whose outputs are
    bit-identical across paths (T=8 sits below MIN_FUSE_T and documents
    the fallback: both paths are the staged host there).  PageRank at
    T=128 rides along as the float-op context row.
    """
    import repro.taf.compile as tc
    from repro.taf import HistoricalGraphStore

    events, cfg, kv, tgi = _build()
    store = HistoricalGraphStore.from_tgi(tgi)
    t0g, t1g = events.time_range()
    t0 = int(t0g + 0.4 * (t1g - t0g))

    def query(op, T):
        ts = np.linspace(t0, t1g, T).astype(np.int64)
        return (store.subgraphs(t0, int(t1g))
                .node_compute(op, style="temporal", points=ts))

    def measure(op, T):
        q = query(op, T)
        q.run()  # warm: traces + uploads the operand off the clock
        hits0, tr0 = tc.STATS["compile_hits"], tc.STATS["traces"]
        us_f = _timeit(lambda: q.run(), repeat=2)
        hits = tc.STATS["compile_hits"] - hits0
        assert tc.STATS["traces"] == tr0, "timed fused runs re-traced"
        with tc.disabled():
            q.run()  # warm the replay/fetch caches identically
            us_s = _timeit(lambda: q.run(), repeat=2)
        return us_f, us_s, hits

    ratio_128 = None
    for T in (8, 32, 128):
        us_f, us_s, hits = measure(tc.components(iters=32), T)
        ratio = us_s / max(us_f, 1e-9)
        if T >= tc.MIN_FUSE_T:
            _row(f"fusion/components_T{T}_fused", us_f,
                 f"staged_us={us_s:.0f};speedup={ratio:.1f}x;"
                 f"cache_hits={hits}")
        else:
            _row(f"fusion/components_T{T}_fallback", us_f,
                 f"staged_us={us_s:.0f};both_staged=1")
        if T == 128:
            ratio_128 = ratio
    us_f, us_s, hits = measure(tc.pagerank(iters=20), 128)
    _row("fusion/pagerank_T128_fused", us_f,
         f"staged_us={us_s:.0f};speedup={us_s / max(us_f, 1e-9):.1f}x;"
         f"cache_hits={hits}")
    if SCALE >= 1.0:
        assert ratio_128 is not None and ratio_128 >= 3.0, \
            f"fused T=128 speedup {ratio_128:.2f}x < 3x gate"
    _row("fusion/speedup_T128_gate", 0.0,
         f"speedup={ratio_128:.1f}x;gate=3x;"
         f"asserted={1 if SCALE >= 1.0 else 0}")


def bench_concurrency():
    """MVCC maintenance interference: snapshot-query latency while the
    background maintenance thread compacts micro-spans and an ingester
    appends, vs the same workload on an idle store.  Readers pin an
    epoch per query, so maintenance costs them cache invalidations and
    lock handoffs — never blocking or torn reads.  Gate (asserted at
    full scale; smoke runs report only): busy p99 <= 2x idle p99, and a
    reader pinned through the churn re-reads its epoch bit-identically.
    """
    import threading

    from repro.core.tgi import TGI, TGIConfig
    from repro.data.temporal_graph_gen import generate
    from repro.storage.kvstore import DeltaStore

    n = N_EVENTS
    events = generate(n, seed=7)
    n0 = int(n * 0.7)
    cfg = TGIConfig(n_shards=4, parts_per_shard=2,
                    events_per_span=max(n // 40, 50),
                    eventlist_size=256, checkpoints_per_span=4)
    tgi = TGI.build(events.take(slice(0, n0)), cfg,
                    DeltaStore(m=4, r=1, backend="mem"))
    rest = events.take(slice(n0, n))
    t0, t1 = events.take(slice(0, n0)).time_range()
    rng = np.random.default_rng(3)
    n_q = max(int(250 * SCALE), 60)

    def sample(k):
        lat = np.empty(k)
        for i in range(k):
            t = int(rng.integers(t0, t1 + 1))  # fresh t: no LRU flattery
            s = time.perf_counter()
            tgi.get_snapshot(t)
            lat[i] = time.perf_counter() - s
        return lat * 1e6

    sample(8)  # warm
    idle = sample(n_q)
    p50_i, p99_i = np.percentile(idle, [50, 99])

    # witness on its OWN thread (a guard is thread-local): pins the
    # pre-churn epoch, re-reads the same t after every swap and deferred
    # delete has happened, and must see bit-identical state
    tq = int(rng.integers(t0, t1 + 1))
    wit_go = threading.Event()
    wit_ok: list = []

    def witness():
        with tgi.read_guard():
            b = tgi.get_snapshot(tq)
            wit_go.wait(timeout=600)
            a = tgi.get_snapshot(tq)
            wit_ok.append(
                np.array_equal(b.present, a.present)
                and np.array_equal(b.attrs, a.attrs)
                and np.array_equal(b.edge_key, a.edge_key)
                and np.array_equal(b.edge_val, a.edge_val))

    wt = threading.Thread(target=witness, daemon=True)
    wt.start()
    time.sleep(0.01)  # let the witness pin before the first swap

    # busy samples are taken ONLY while a maintenance pass is actually
    # running: ingest accretes micro-spans off the clock, then a pass
    # merges them on the background thread while the foreground queries
    # race it (each sample pins its own fresh epoch — post-swap cold
    # reads are part of the measured cost)
    busy_l: list = []
    lo, passes0 = 0, tgi.maintenance_stats["passes"]
    batch = max(cfg.events_per_span // 2, 10)  # half-span micro batches
    while lo < len(rest):
        for _ in range(6):  # off the clock: accrete compactable spans
            hi = min(lo + batch, len(rest))
            if hi > lo:
                tgi.update(rest.take(slice(lo, hi)))
                lo = hi
        fut = tgi.compact(min_run=2, wait=False)
        while not fut.done():
            busy_l.extend(sample(1))
        fut.result()
    assert tgi.maintenance_stats["passes"] > passes0, \
        "no maintenance pass overlapped the busy sampling window"
    assert len(busy_l) >= 20, \
        f"too few mid-compaction samples ({len(busy_l)}) for a p99"
    busy = np.array(busy_l)
    wit_go.set()
    wt.join(timeout=120)
    assert wit_ok == [True], \
        "pinned-epoch re-read not bit-identical across maintenance"
    tgi.compact(min_run=2)  # settle: drain the deferred-GC queue
    assert tgi.store.gc_pending() == 0
    p50_b, p99_b = np.percentile(busy, [50, 99])
    ratio = p99_b / max(p99_i, 1e-9)
    ms = tgi.maintenance_stats
    _row("concurrency/query_idle", p50_i, f"p99_us={p99_i:.0f};n={n_q}")
    _row("concurrency/query_during_compaction", p50_b,
         f"p99_us={p99_b:.0f};p99_ratio={ratio:.2f}x;"
         f"passes={ms['passes']};gc_deferred={ms['gc_deferred_keys']}")
    if SCALE >= 1.0:
        assert ratio <= 2.0, \
            f"busy p99 {p99_b:.0f}us > 2x idle p99 {p99_i:.0f}us"
    _row("concurrency/p99_gate", 0.0,
         f"ratio={ratio:.2f}x;gate=2x;asserted={1 if SCALE >= 1.0 else 0}")


BENCHES: Dict[str, Callable] = {
    "fig11": fig11_snapshot_vs_c,
    "fig12": fig12_snapshot_vs_m_r,
    "fig13b": fig13b_snapshot_vs_ps,
    "fig14": fig14_node_history,
    "fig15a": fig15a_1hop_partitioning,
    "fig15b": fig15b_growing_data,
    "fig15c": fig15c_taf_scaling,
    "fig17": fig17_incremental_vs_temporal,
    "pushdown": bench_query_pushdown,
    "fetch": bench_fetch,
    "replay": bench_replay,
    "snapshots": bench_batched_snapshots,
    "storage": bench_storage,
    "ingest": bench_ingest,
    "service": bench_service,
    "transport": bench_transport,
    "multiwriter": bench_multiwriter,
    "table1": table1_index_comparison,
    "ckpt": bench_checkpoint_store,
    "kernel": bench_delta_overlay_kernel,
    "fusion": bench_fusion,
    "concurrency": bench_concurrency,
}


def main() -> None:
    global REPEAT_OVERRIDE
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--repeat", type=int, default=None,
                    help="override per-bench repeat counts (1 = smoke mode)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="persist rows as JSON (the BENCH_*.json trajectory)")
    args, _ = ap.parse_known_args()
    REPEAT_OVERRIDE = args.repeat
    names = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()
    if args.json:
        payload = {
            "meta": {
                "benches": names,
                "n_events": N_EVENTS,
                "scale": SCALE,
                "repeat_override": REPEAT_OVERRIDE,
                "python": platform.python_version(),
                "platform": platform.platform(),
            },
            "rows": RESULTS,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(RESULTS)} rows -> {args.json}", flush=True)


if __name__ == "__main__":
    main()
