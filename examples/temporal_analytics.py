"""Temporal analytics with TAF operators: community comparison (paper
Fig 7b), evolution + temporal aggregation (7c), the incremental-vs-
version computation pair (Fig 8 / 17), and PageRank over time.

  PYTHONPATH=src python examples/temporal_analytics.py
"""
import time

import numpy as np

from repro.core.tgi import TGI, TGIConfig
from repro.data.temporal_graph_gen import generate
from repro.storage.kvstore import DeltaStore
from repro.taf import analytics, build_sots
from repro.taf import operators as ops

events = generate(n_events=10_000, seed=1)
t0g, t1g = events.time_range()
cfg = TGIConfig(n_shards=4, parts_per_shard=2, events_per_span=2_500)
tgi = TGI.build(events, cfg, DeltaStore(m=4, r=1, backend="mem"))

t0 = int(t0g + 0.3 * (t1g - t0g))
t1 = int(t0g + 0.9 * (t1g - t0g))
sots = build_sots(tgi, t0, t1)
print(f"SoTS: {len(sots)} temporal nodes over ({t0}, {t1}]")

# --- compare two "communities" (label-0 vs label-1 nodes), Fig 7b style
com_a = ops.selection(sots, lambda s: s.init_attrs[:, 0] == 0)
com_b = ops.selection(sots, lambda s: s.init_attrs[:, 0] == 1)


def mean_degree(son, t):
    _, deg = analytics.degree_series_delta(son, points=[t])
    return float(deg[son.init_present == 1].mean())


tm = (t0 + t1) // 2
print(f"community A ({len(com_a)} nodes) mean degree @tm: {mean_degree(com_a, tm):.2f}")
print(f"community B ({len(com_b)} nodes) mean degree @tm: {mean_degree(com_b, tm):.2f}")

# --- evolution + temporal aggregation (Fig 7c + operator 9)
pts, dens = analytics.density_evolution(sots, n_samples=10)
print("density peak timepoints:", ops.temp_aggregate(dens, "peak", pts))
print("density mean:", f"{ops.temp_aggregate(dens, 'mean'):.5f}")

# --- incremental vs per-version computation (Fig 8 / Fig 17)
label = int(np.bincount(sots.init_attrs[:, 0][sots.init_attrs[:, 0] >= 0]).argmax())
pts = sots.change_points()[::4][:64]
w0 = time.perf_counter()
_, a = analytics.label_count_temporal(sots, label, points=pts)
t_temporal = time.perf_counter() - w0
w0 = time.perf_counter()
_, b = analytics.label_count_delta(sots, label, points=pts)
t_delta = time.perf_counter() - w0
on = sots.init_present == 1
assert np.allclose(a[on], b[on])
print(f"label-count over {len(pts)} versions: "
      f"NodeComputeTemporal {t_temporal*1e3:.0f}ms vs "
      f"NodeComputeDelta {t_delta*1e3:.0f}ms "
      f"({t_temporal / max(t_delta, 1e-9):.1f}x)")

# --- PageRank over time with warm starts
pts = np.linspace(t0, t1, 6).astype(np.int64)
ranks, iters = analytics.pagerank_over_time(sots, pts, warm_start=True)
_, iters_cold = analytics.pagerank_over_time(sots, pts, warm_start=False)
top = sorted(ranks[-1], key=ranks[-1].get)[-3:]
print(f"top-3 PageRank at t1: {top}; warm-start iterations {iters} "
      f"vs cold {iters_cold}")
