"""Temporal analytics through the unified query surface: community
comparison (paper Fig 7b), evolution + temporal aggregation (7c), the
incremental-vs-version computation pair (Fig 8 / 17), PageRank over
time, and the planner's fetch pushdown.

Everything goes through HistoricalGraphStore / TemporalQuery: the chain
is lazy, compiles to a typed Plan (see .explain()), and the executor
applies partition pruning + projection before touching storage.

  PYTHONPATH=src python examples/temporal_analytics.py
"""
import time

import numpy as np

from repro.core.events import EDGE_ADD, EDGE_DEL
from repro.data.temporal_graph_gen import generate
from repro.storage.kvstore import DeltaStore
from repro.taf import HistoricalGraphStore, analytics, operators as ops

events = generate(n_events=10_000, seed=1)
store = HistoricalGraphStore.build(
    events, n_shards=4, parts_per_shard=2, events_per_span=2_500,
    store=DeltaStore(m=4, r=1, backend="mem"))
t0g, t1g = store.time_range()

t0 = int(t0g + 0.3 * (t1g - t0g))
t1 = int(t0g + 0.9 * (t1g - t0g))
tm = (t0 + t1) // 2

# one fetch, many computes: materialize the SoTS operand once
q = store.subgraphs(t0, t1).materialize()
sots = q.operand
print(f"SoTS: {len(sots)} temporal nodes over ({t0}, {t1}] "
      f"({store.last_cost.n_deltas} deltas fetched)")

# --- compare two "communities" (label-0 vs label-1 nodes), Fig 7b style


def deg_init(present, attrs, son, i, init):
    deg = son.adj_indptr[i + 1] - son.adj_indptr[i]
    return None, float(deg if present else 0)


def deg_delta(aux, val, kind, key, val_, other, i, son):
    if kind == EDGE_ADD:
        return aux, val + 1.0
    if kind == EDGE_DEL:
        return aux, val - 1.0
    return aux, val


for name, label in (("A", 0), ("B", 1)):
    com = (q.filter(lambda s, _l=label: s.init_attrs[:, 0] == _l,
                    label=f"attr0=={label}")
            .timeslice(tm)
            .node_compute(deg_init, style="delta", f_delta=deg_delta,
                          label="degree"))
    r = com.run()
    deg = r.value[1][:, 0]
    on = r.operand.init_present == 1
    print(f"community {name} ({len(r.operand)} nodes) "
          f"mean degree @tm: {deg[on].mean():.2f}")
print(com.explain())

# --- evolution + temporal aggregation (Fig 7c + operator 9)


def density(son, t):
    g = ops.graph(son, t)
    n = int(g.present.sum())
    e = len(g.edge_key)
    return 0.0 if n < 2 else 2.0 * e / (n * (n - 1))


pts, dens = q.evolution(density, n_samples=10).execute()
print("density peak timepoints:", ops.temp_aggregate(dens, "peak", pts))
print("density mean:", f"{ops.temp_aggregate(dens, 'mean'):.5f}")

# --- incremental vs per-version computation (Fig 8 / Fig 17)
label = int(np.bincount(sots.init_attrs[:, 0][sots.init_attrs[:, 0] >= 0]).argmax())
pts = sots.change_points()[::4][:64]
w0 = time.perf_counter()
_, a = analytics.label_count_temporal(sots, label, points=pts)
t_temporal = time.perf_counter() - w0
w0 = time.perf_counter()
_, b = analytics.label_count_delta(sots, label, points=pts)
t_delta = time.perf_counter() - w0
on = sots.init_present == 1
assert np.allclose(a[on], b[on])
print(f"label-count over {len(pts)} versions: "
      f"NodeComputeTemporal {t_temporal*1e3:.0f}ms vs "
      f"NodeComputeDelta {t_delta*1e3:.0f}ms "
      f"({t_temporal / max(t_delta, 1e-9):.1f}x)")

# --- fetch pushdown: a selective query reads fewer shards + no attrs
full_cost = store.nodes(t0, t1).run().cost
hub = int(sots.node_ids[np.argmax(np.diff(sots.adj_indptr))])
sel = (store.nodes(t0, t1)
       .filter(node_ids=[hub])
       .khop(1)
       .project(attrs=False)
       .timeslice(tm)
       .node_compute(deg_init, style="delta", f_delta=deg_delta))
r = sel.run()
print(f"pushdown: hub degree @tm = {r.value[1][0, 0]:.0f} via "
      f"{r.cost.n_deltas} deltas / {r.cost.n_bytes}B "
      f"(full fetch: {full_cost.n_deltas} deltas / {full_cost.n_bytes}B)")

# --- PageRank over time with warm starts
pts = np.linspace(t0, t1, 6).astype(np.int64)
ranks, iters = analytics.pagerank_over_time(sots, pts, warm_start=True)
_, iters_cold = analytics.pagerank_over_time(sots, pts, warm_start=False)
top = sorted(ranks[-1], key=ranks[-1].get)[-3:]
print(f"top-3 PageRank at t1: {top}; warm-start iterations {iters} "
      f"vs cold {iters_cold}")
