"""End-to-end driver: train a (reduced) assigned architecture on random
walks over the temporal graph — the graph plane feeding the LM plane —
with TGI-backed delta checkpointing, a simulated crash, and an elastic
resume.  ~2-3 minutes on CPU.

  PYTHONPATH=src python examples/train_lm.py [--arch granite-3-8b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.tgi import TGI, TGIConfig
from repro.data.pipeline import GraphWalkLM, PipelineConfig
from repro.data.temporal_graph_gen import generate
from repro.models import lm
from repro.models.sharding import Sharder, split_tree
from repro.optim import adamw
from repro.storage.checkpoint import CheckpointConfig, CheckpointStore
from repro.storage.kvstore import DeltaStore
from repro.train import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-1.7b")
ap.add_argument("--steps", type=int, default=24)
args = ap.parse_args()

BATCH, SEQ = 8, 64
cfg = get_config(args.arch).reduced()
print(f"arch {args.arch} (reduced): {cfg.n_layers}L d={cfg.d_model}")

# --- graph plane: history + index + walk dataset
events = generate(6_000, seed=3)
tgi = TGI.build(events, TGIConfig(n_shards=2, parts_per_shard=2,
                                  events_per_span=2_000),
                DeltaStore(m=2, r=1, backend="mem"))
pipe = GraphWalkLM(PipelineConfig(BATCH, SEQ, cfg.vocab_size), tgi, seed=0)
print("pipeline: random walks over TGI snapshots at "
      f"{len(pipe.times)} timepoints")

# --- LM plane
shd = Sharder(mesh=None)
params, _ = split_tree(lm.init(jax.random.PRNGKey(0), cfg, max_seq=4 * SEQ))
opt_state = adamw.init(params)
ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=4, decay_steps=args.steps)
step_fn = jax.jit(make_train_step(cfg, shd, ocfg))

ckpt = CheckpointStore(DeltaStore(m=4, r=2, backend="mem"),
                       CheckpointConfig(snapshot_every=3))


def extra_inputs(step):
    out = {}
    if cfg.n_img_tokens:
        out["img_embeds"] = np.zeros((BATCH, cfg.n_img_tokens, cfg.d_model), np.float32)
    if cfg.is_encdec:
        out["frames"] = (np.random.RandomState(step)
                         .randn(BATCH, cfg.enc_seq, cfg.d_model).astype(np.float32) * 0.02)
    return out


crash_at = args.steps * 2 // 3
crashed = False
losses = []
step = 0
while step < args.steps:
    batch = dict(pipe.batch(step), **extra_inputs(step))
    params, opt_state, metrics = step_fn(
        params, opt_state, {k: jnp.asarray(v) for k, v in batch.items()})
    losses.append(float(metrics["loss"]))
    if step % 4 == 0:
        print(f"step {step:3d} loss {losses[-1]:.4f}")
    if (step + 1) % 4 == 0:
        ckpt.save(step, (params, opt_state))
    if step == crash_at and not crashed:
        crashed = True
        print(f"--- simulated crash after step {step}; killing storage node 1 "
              "and restoring from replicas ---")
        ckpt.store.fail_node(1)
        (params, opt_state), restored = ckpt.restore(c=4, example_tree=(params, opt_state))
        step = restored + 1
        print(f"--- resumed from step {restored} (failovers: "
              f"{ckpt.store.stats.failovers}) ---")
        continue
    step += 1

print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
      f"checkpoint store wrote {ckpt.storage_cost()['bytes_written']/1e6:.1f} MB "
      f"across {ckpt.storage_cost()['n_saves']} saves "
      f"(delta saves compress vs snapshots)")
assert losses[-1] < losses[0], "training should reduce loss"
print("OK")
