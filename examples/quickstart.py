"""Quickstart: index a synthetic history behind the HistoricalGraphStore
facade, run the paper's retrieval primitives, and the Fig-7a analytics
example through the lazy TemporalQuery surface.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.tgi import TGIConfig
from repro.data.temporal_graph_gen import generate
from repro.storage.kvstore import DeltaStore
from repro.taf import HistoricalGraphStore

# 1. a synthetic temporal graph: 20k events, bursty + preferential
events = generate(n_events=20_000, seed=42)
t0, t1 = events.time_range()
print(f"history: {len(events)} events over [{t0}, {t1}], "
      f"{events.n_nodes} node ids")

# 2. index it behind the facade: 4 horizontal shards x 2 micro-partitions,
#    4 checkpoints per timespan, on an in-memory 4-node store with r=2
cfg = TGIConfig(n_shards=4, parts_per_shard=2, events_per_span=5_000,
                eventlist_size=256, checkpoints_per_span=4)
kv = DeltaStore(m=4, r=2, backend="mem")
store = HistoricalGraphStore.build(events, cfg=cfg, store=kv)
print(f"index: {len(store.tgi.spans)} timespans, "
      f"{kv.stats.bytes_written / 1e6:.1f} MB written")

# 3. snapshot retrieval (Algorithm 1) — any point in the past
t = (t0 + t1) // 2
g = store.snapshot(t, c=4)
print(f"snapshot@{t}: {int(g.present.sum())} nodes, {len(g.edge_key)} edges "
      f"({store.last_cost.n_deltas} deltas fetched)")

# 4. node history (Algorithm 2)
hub = int(np.argmax(g.degree()))
init, ev = store.node_history(hub, t, t1)
print(f"node {hub} history: initial degree {len(init['neighbors'])}, "
      f"{len(ev)} change events in ({t}, {t1}]")

# 5. k-hop neighborhood (Algorithm 3/4)
hood = store.k_hop(hub, t, k=2)
print(f"2-hop of {hub}: {int(hood.present.sum())} nodes, {len(hood.edge_key)} edges")

# 6. survive a storage-node failure (replication r=2).  Drop the
# snapshot LRU first so the read really hits storage, not the cache.
kv.fail_node(0)
store.tgi.invalidate_caches()
g2 = store.snapshot(t, c=4)
assert (g2.edge_key == g.edge_key).all()
kv.heal_node(0)
print(f"snapshot identical with node 0 down (failovers: {kv.stats.failovers})")

# 7. TAF via the lazy query surface: fetch the SoTS operand once, then
#    the paper's Fig-7a example + density evolution over it
q = store.subgraphs(t, t1).materialize()
from repro.taf import analytics  # noqa: E402

nid, lcc = analytics.max_lcc(q.operand, t)
print(f"max LCC at t={t}: node {nid} (LCC={lcc:.3f})")

pts, dens = analytics.density_evolution(q.operand, n_samples=8)
print("density evolution:", ", ".join(f"{d:.4f}" for d in dens))
