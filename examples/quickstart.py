"""Quickstart: build a Temporal Graph Index over a synthetic history and
run the paper's retrieval primitives + the Fig-7a analytics example.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.tgi import TGI, TGIConfig
from repro.data.temporal_graph_gen import generate
from repro.storage.kvstore import DeltaStore
from repro.taf import analytics, build_sots

# 1. a synthetic temporal graph: 20k events, bursty + preferential
events = generate(n_events=20_000, seed=42)
t0, t1 = events.time_range()
print(f"history: {len(events)} events over [{t0}, {t1}], "
      f"{events.n_nodes} node ids")

# 2. index it: 4 horizontal shards x 2 micro-partitions, 4 checkpoints
#    per timespan, on an in-memory 4-node store with replication 2
cfg = TGIConfig(n_shards=4, parts_per_shard=2, events_per_span=5_000,
                eventlist_size=256, checkpoints_per_span=4)
store = DeltaStore(m=4, r=2, backend="mem")
tgi = TGI.build(events, cfg, store)
print(f"index: {len(tgi.spans)} timespans, "
      f"{store.stats.bytes_written / 1e6:.1f} MB written")

# 3. snapshot retrieval (Algorithm 1) — any point in the past
t = (t0 + t1) // 2
g = tgi.get_snapshot(t, c=4)
print(f"snapshot@{t}: {int(g.present.sum())} nodes, {len(g.edge_key)} edges "
      f"({tgi.last_cost.n_deltas} deltas fetched)")

# 4. node history (Algorithm 2)
hub = int(np.argmax(g.degree()))
init, ev = tgi.get_node_history(hub, t, t1)
print(f"node {hub} history: initial degree {len(init['neighbors'])}, "
      f"{len(ev)} change events in ({t}, {t1}]")

# 5. k-hop neighborhood (Algorithm 3/4)
hood = tgi.get_k_hop(hub, t, k=2)
print(f"2-hop of {hub}: {int(hood.present.sum())} nodes, {len(hood.edge_key)} edges")

# 6. survive a storage-node failure (replication r=2)
store.fail_node(0)
g2 = tgi.get_snapshot(t, c=4)
assert (g2.edge_key == g.edge_key).all()
store.heal_node(0)
print(f"snapshot identical with node 0 down (failovers: {store.stats.failovers})")

# 7. TAF: the paper's Fig-7a example — node with the highest local
#    clustering coefficient at a historical timeslice
sots = build_sots(tgi, t, t1)
nid, lcc = analytics.max_lcc(sots, t)
print(f"max LCC at t={t}: node {nid} (LCC={lcc:.3f})")

pts, dens = analytics.density_evolution(sots, n_samples=8)
print("density evolution:", ", ".join(f"{d:.4f}" for d in dens))
