"""Docs-freshness check: execute the ```python code blocks of the given
markdown files against the installed package.

Blocks in one file run top-to-bottom in a single shared namespace, so a
quickstart block can define names that later blocks use — exactly what a
reader pasting the snippets into one session would experience.  Blocks
fenced as anything but ```python (```text, bare ```) are ignored, and a
```python block can be opted out with an HTML comment on the line above
the fence:

    <!-- doc-test: skip -->
    ```python
    ...pseudo-code...
    ```

Usage:  PYTHONPATH=src python tools/run_doc_snippets.py README.md docs/api.md
"""
from __future__ import annotations

import pathlib
import re
import sys

FENCE_RE = re.compile(
    r"(?P<prefix>^|\n)(?P<skip><!--\s*doc-test:\s*skip\s*-->\s*\n)?"
    r"```python[^\n]*\n(?P<body>.*?)\n```",
    re.DOTALL,
)


def extract_blocks(text: str):
    for m in FENCE_RE.finditer(text):
        if m.group("skip"):
            continue
        lineno = text[: m.start("body")].count("\n") + 1
        yield lineno, m.group("body")


def run_file(path: pathlib.Path) -> int:
    ns: dict = {"__name__": f"doc_snippets:{path.name}"}
    n = 0
    for lineno, body in extract_blocks(path.read_text()):
        n += 1
        code = compile(body, f"{path}:{lineno}", "exec")
        try:
            exec(code, ns)
        except Exception:
            print(f"FAIL {path} block #{n} (line {lineno})", file=sys.stderr)
            raise
        print(f"ok   {path} block #{n} (line {lineno})")
    if n == 0:
        print(f"warn {path}: no runnable python blocks", file=sys.stderr)
    return n


def main(argv):
    if not argv:
        argv = ["README.md", "docs/api.md"]
    total = 0
    for name in argv:
        total += run_file(pathlib.Path(name))
    print(f"{total} doc snippet(s) executed")


if __name__ == "__main__":
    main(sys.argv[1:])
