"""Per-kernel validation: shape/dtype sweeps, assert_allclose against the
ref.py pure-jnp oracles (interpret mode on CPU; same pallas_call lowers on
TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.delta_overlay import ops as ov_ops
from repro.kernels.delta_overlay import ref as ov_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.rglru_scan import ops as rg_ops
from repro.kernels.rglru_scan import ref as rg_ref

# ---------------------------------------------------------------------------
# delta_overlay
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,P,S,K", [(2, 1, 256, 1), (4, 3, 256, 4),
                                     (8, 2, 512, 2), (3, 2, 300, 3)])
def test_delta_overlay_matches_ref(h, P, S, K):
    rng = np.random.RandomState(h * 100 + P)
    valid = rng.rand(h, P, S) < 0.4
    present = (rng.rand(h, P, S) < 0.7).astype(np.int8)
    attrs = rng.randint(-1, 5, size=(h, P, S, K)).astype(np.int32)
    got = ov_ops.overlay(valid, present, attrs, use_pallas=True)
    want = ov_ref.overlay_ref(jnp.asarray(valid), jnp.asarray(present),
                              jnp.asarray(attrs))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_delta_overlay_matches_numpy_chain():
    """Kernel == the numpy Δ-sum chain used by core.delta (_node_sum)."""
    from repro.core.delta import Delta, delta_sum

    rng = np.random.RandomState(0)
    h, P, S, K = 4, 2, 256, 3
    ds = []
    for i in range(h):
        d = Delta.empty(P, S, K)
        d.valid = rng.rand(P, S) < 0.5
        d.present = np.where(d.valid, (rng.rand(P, S) < 0.8), 0).astype(np.int8)
        d.attrs = np.where(
            (d.valid & (d.present == 1))[..., None],
            rng.randint(-1, 4, size=(P, S, K)), -1
        ).astype(np.int32)
        ds.append(d)
    acc = ds[0]
    for d in ds[1:]:
        acc = delta_sum(acc, d)
    got_v, got_p, got_a = ov_ops.overlay(
        np.stack([d.valid for d in ds]),
        np.stack([d.present for d in ds]),
        np.stack([d.attrs for d in ds]),
    )
    np.testing.assert_array_equal(np.asarray(got_v), acc.valid)
    on = acc.valid
    np.testing.assert_array_equal(np.asarray(got_p)[on], acc.present[on])
    np.testing.assert_array_equal(np.asarray(got_a)[on], acc.attrs[on])


@pytest.mark.parametrize("h,P,S,K,T", [(2, 1, 256, 1, 1), (4, 2, 256, 3, 4),
                                       (6, 2, 300, 2, 3), (8, 1, 512, 2, 8)])
def test_delta_overlay_batch_matches_ref(h, P, S, K, T):
    """Time-batched kernel (interpret mode) == pure-jnp batch oracle,
    bit-for-bit, including masked-out layers."""
    rng = np.random.RandomState(h * 10 + T)
    valid = rng.rand(h, P, S) < 0.4
    present = (rng.rand(h, P, S) < 0.7).astype(np.int8)
    attrs = rng.randint(-1, 5, size=(h, P, S, K)).astype(np.int32)
    tmask = (rng.rand(h, T) < 0.6).astype(np.int8)
    tmask[0, :] = 1  # at least one shared layer per timepoint
    got = ov_ops.overlay_batch(valid, present, attrs, tmask, use_pallas=True)
    want = ov_ref.overlay_batch_ref(
        jnp.asarray(valid, jnp.int8), jnp.asarray(present),
        jnp.asarray(attrs), jnp.asarray(tmask, jnp.int32))
    assert got[0].shape == (P, S, T)
    assert got[2].shape == (P, S, T, K)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]) != 0)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))


def test_delta_overlay_batch_matches_per_t_overlay():
    """Each timepoint's column == the single-timepoint overlay of its
    selected layers (on valid slots, with delta-invariant inputs:
    attrs set only where present)."""
    rng = np.random.RandomState(7)
    h, P, S, K, T = 5, 2, 256, 3, 4
    valid = rng.rand(h, P, S) < 0.5
    present = np.where(valid, (rng.rand(h, P, S) < 0.8), 0).astype(np.int8)
    attrs = np.where((valid & (present == 1))[..., None],
                     rng.randint(-1, 4, size=(h, P, S, K)), -1).astype(np.int32)
    # column t folds the shared prefix [0, 1] plus its own layer 2 + t
    tmask = np.zeros((h, T), np.int8)
    tmask[:2, :] = 1
    for t in range(min(T, h - 2)):
        tmask[2 + t, t] = 1
    got_v, got_p, got_a = (np.asarray(x) for x in
                           ov_ops.overlay_batch(valid, present, attrs, tmask))
    for t in range(T):
        layers = np.nonzero(tmask[:, t])[0]
        w_v, w_p, w_a = ov_ops.overlay(
            valid[layers], present[layers], attrs[layers], use_pallas=True)
        w_v, w_p, w_a = np.asarray(w_v), np.asarray(w_p), np.asarray(w_a)
        np.testing.assert_array_equal(got_v[..., t], w_v)
        on = w_v & (w_p == 1)
        np.testing.assert_array_equal(got_p[..., t][w_v], w_p[w_v])
        np.testing.assert_array_equal(got_a[:, :, t][on], w_a[on])


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,Sq,Sk,D,causal,window,dtype", [
    (1, 2, 64, 64, 32, True, 0, jnp.float32),
    (2, 1, 128, 128, 16, True, 0, jnp.bfloat16),
    (1, 2, 96, 160, 32, True, 48, jnp.float32),   # sliding window + padding
    (1, 1, 64, 256, 64, False, 0, jnp.float32),   # cross attention
    (2, 2, 1, 96, 32, True, 0, jnp.float32),      # decode-style single query
])
def test_flash_attention_matches_ref(B, H, Sq, Sk, D, causal, window, dtype):
    rng = jax.random.PRNGKey(B * 7 + Sk)
    ks = jax.random.split(rng, 3)
    q = (jax.random.normal(ks[0], (B, H, Sq, D)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, H, Sk, D)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, H, Sk, D)) * 0.5).astype(dtype)
    q_pos = jnp.arange(Sk - Sq, Sk, dtype=jnp.int32) if causal else jnp.arange(Sq, dtype=jnp.int32)
    k_pos = jnp.arange(Sk, dtype=jnp.int32)
    got = fa_ops.flash_attention(q, k, v, q_pos, k_pos, causal=causal,
                                 window=window, blk_q=32, blk_k=32)
    want = fa_ref.attention_ref(q, k, v, q_pos, k_pos, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_attention_ring_cache_holes():
    """k_pos = -1 holes (unfilled ring-buffer slots) are masked out."""
    B, H, S, D = 1, 1, 64, 16
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, H, 1, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    k_pos = jnp.where(jnp.arange(S) < 40, jnp.arange(S), -1).astype(jnp.int32)
    q_pos = jnp.asarray([39], jnp.int32)
    got = fa_ops.flash_attention(q, k, v, q_pos, k_pos, blk_q=8, blk_k=16)
    want = fa_ref.attention_ref(q, k, v, q_pos, k_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# rglru_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,W,chunk", [(1, 128, 128, 32), (2, 64, 256, 16),
                                         (1, 96, 130, 32), (2, 33, 64, 16)])
def test_rglru_matches_associative_scan(B, S, W, chunk):
    rng = np.random.RandomState(S + W)
    log_a = -np.abs(rng.randn(B, S, W)).astype(np.float32) * 0.5
    b = rng.randn(B, S, W).astype(np.float32)
    got = rg_ops.rglru(jnp.asarray(log_a), jnp.asarray(b), chunk=chunk, tile_w=64)
    want = rg_ref.rglru_ref(jnp.asarray(log_a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_rglru_matches_sequential():
    B, S, W = 1, 40, 32
    rng = np.random.RandomState(3)
    log_a = -np.abs(rng.randn(B, S, W)).astype(np.float32)
    b = rng.randn(B, S, W).astype(np.float32)
    h = np.zeros((B, W), np.float32)
    seq = []
    for t in range(S):
        h = np.exp(log_a[:, t]) * h + b[:, t]
        seq.append(h.copy())
    want = np.stack(seq, 1)
    got = rg_ops.rglru(jnp.asarray(log_a), jnp.asarray(b), chunk=8, tile_w=32)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# model-level integration: blockwise == direct == pallas paths
# ---------------------------------------------------------------------------


def test_model_attention_impls_agree():
    from repro.models.attention import blockwise_attention, direct_attention

    B, S, H, D = 2, 96, 2, 32
    rng = jax.random.PRNGKey(1)
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, H, D)) * 0.3
    k = jax.random.normal(ks[1], (B, S, H, D)) * 0.3
    v = jax.random.normal(ks[2], (B, S, H, D)) * 0.3
    pos = jnp.arange(S, dtype=jnp.int32)
    a = blockwise_attention(q, k, v, pos, pos, causal=True, window=0,
                            blk_q=32, blk_k=32)
    b = direct_attention(q, k, v, pos, pos, causal=True, window=0, logit_cap=0.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)
    c = fa_ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        pos, pos, causal=True, blk_q=32, blk_k=32,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(c), np.asarray(b), atol=1e-5, rtol=1e-5)


def test_mlstm_chunkwise_equals_stepwise():
    from repro.models.xlstm_blocks import mlstm_chunkwise, mlstm_step

    B, S, H, d = 2, 64, 2, 16
    rng = jax.random.PRNGKey(5)
    ks = jax.random.split(rng, 5)
    q = jax.random.normal(ks[0], (B, S, H, d)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, d)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, d)) * 0.5
    i_pre = jax.random.normal(ks[3], (B, S, H))
    f_pre = jax.random.normal(ks[4], (B, S, H)) + 2.0
    h_chunk, _ = mlstm_chunkwise(q, k, v, i_pre, f_pre, chunk=16)
    hs = []
    state = (jnp.zeros((B, H, d, d)), jnp.zeros((B, H, d)), jnp.zeros((B, H)))
    for t in range(S):
        h, state = mlstm_step(q[:, t], k[:, t], v[:, t], i_pre[:, t], f_pre[:, t], state)
        hs.append(h)
    h_step = jnp.stack(hs, 1)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_step),
                               atol=2e-4, rtol=2e-3)


# ---------------------------------------------------------------------------
# temporal analytics family (pagerank / connected components / motifs)
# ---------------------------------------------------------------------------


def _random_temporal_graphs(seed, T=3, N=40, p=0.08):
    """(T, N, N) symmetric 0/1 adjacency (zero diagonal) + (T, N) active
    masks; edges only between active nodes."""
    rng = np.random.RandomState(seed)
    active = (rng.rand(T, N) < 0.8).astype(np.int32)
    adj = (rng.rand(T, N, N) < p).astype(np.float32)
    adj = np.maximum(adj, adj.transpose(0, 2, 1))
    for j in range(T):
        adj[j] *= active[j][:, None] * active[j][None, :]
        np.fill_diagonal(adj[j], 0.0)
    return adj, active


@pytest.mark.parametrize("seed,N", [(0, 40), (1, 130), (2, 256)])
def test_temporal_pagerank_matches_ref(seed, N):
    from repro.kernels.temporal_pagerank import ops as pr_ops
    from repro.kernels.temporal_pagerank import ref as pr_ref

    adj, active = _random_temporal_graphs(seed, N=N)
    got = pr_ops.temporal_pagerank(adj, active, iters=10, use_pallas=True)
    want = pr_ref.pagerank_ref(jnp.asarray(adj), jnp.asarray(active), iters=10)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-5)
    # active ranks form a distribution per timepoint
    sums = np.asarray(got).sum(axis=1)
    np.testing.assert_allclose(sums, np.where(active.sum(1) > 0, 1.0, 0.0),
                               atol=1e-4)


@pytest.mark.parametrize("seed,N", [(3, 40), (4, 130)])
def test_temporal_cc_matches_ref(seed, N):
    from repro.kernels.temporal_cc import ops as cc_ops
    from repro.kernels.temporal_cc import ref as cc_ref

    adj, active = _random_temporal_graphs(seed, N=N)
    got = cc_ops.temporal_cc(adj, active, iters=N, use_pallas=True)
    want = cc_ref.cc_ref(jnp.asarray(adj), jnp.asarray(active), iters=N)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # labels agree with a union-find oracle up to relabeling
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csg

    for j in range(adj.shape[0]):
        n_cc, lab = csg.connected_components(sp.csr_matrix(adj[j]),
                                             directed=False)
        g = np.asarray(got)[j]
        on = active[j] == 1
        # same partition: kernel labels constant on each oracle component
        for c in range(n_cc):
            members = on & (lab == c)
            if members.any():
                assert len(np.unique(g[members])) == 1
        assert (g[~on] == -1).all()


@pytest.mark.parametrize("seed,N", [(5, 40), (6, 130)])
def test_temporal_motif_matches_ref_and_bruteforce(seed, N):
    from repro.kernels.temporal_motif import ops as mo_ops
    from repro.kernels.temporal_motif import ref as mo_ref

    adj, _ = _random_temporal_graphs(seed, N=N, p=0.15)
    got = np.asarray(mo_ops.temporal_motif(adj, use_pallas=True))
    want = np.asarray(mo_ref.motif_ref(jnp.asarray(adj)))
    np.testing.assert_array_equal(got, want)
    # brute-force triangle enumeration at timepoint 0
    a = adj[0]
    brute = np.zeros(N, np.int64)
    idx = np.transpose(np.nonzero(np.triu(a)))
    for u, v in idx:
        common = np.nonzero(a[u] * a[v])[0]
        for w in common:
            if w > v:
                brute[u] += 1
                brute[v] += 1
                brute[w] += 1
    np.testing.assert_array_equal(got[0], brute)
