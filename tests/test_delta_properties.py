"""Property-based tests (hypothesis): the paper's Δ-algebra identities
(§4.1) and TGI system invariants on random event streams."""
import numpy as np
import pytest

# hypothesis is not in the container image; the deterministic suites
# (test_tgi/test_taf/test_query) cover the same invariants on fixed
# streams, so skip rather than fail collection when it is absent
pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core import delta as dm
from repro.core.delta import Delta, delta_difference, delta_intersection, delta_sum, deltas_equal
from repro.core.events import EventLog
from repro.core.slots import SlotMap
from repro.core.snapshot import GraphState, events_to_delta
from repro.core.tgi import TGI, TGIConfig
from repro.data.temporal_graph_gen import generate, naive_state_at
from repro.storage.kvstore import DeltaStore

P, PSIZE, K = 2, 8, 2


@st.composite
def deltas(draw):
    n_valid = draw(st.integers(0, P * PSIZE))
    d = Delta.empty(P, PSIZE, K, ecap=8)
    idx = draw(
        st.lists(st.integers(0, P * PSIZE - 1), min_size=n_valid,
                 max_size=n_valid, unique=True)
    )
    for i in idx:
        p, s = divmod(i, PSIZE)
        d.valid[p, s] = True
        pres = draw(st.integers(0, 1))
        d.present[p, s] = pres
        if pres:
            for k in range(K):
                d.attrs[p, s, k] = draw(st.integers(-1, 3))
    n_e = draw(st.integers(0, 6))
    es = draw(st.lists(
        st.tuples(st.integers(0, P * PSIZE - 1), st.integers(0, 9)),
        min_size=n_e, max_size=n_e, unique=True))
    es.sort()
    for j, (gs, dst) in enumerate(es):
        d.e_src[j] = gs
        d.e_dst[j] = dst
        d.e_op[j] = draw(st.integers(0, 1))
        d.e_val[j] = draw(st.integers(-1, 3))
    return d


@given(deltas())
@settings(max_examples=50, deadline=None)
def test_sum_identity(d):
    empty = Delta.empty(P, PSIZE, K)
    assert deltas_equal(delta_sum(d, empty), d)


@st.composite
def tombstone_free_deltas(draw):
    """Deltas whose valid slots are all present (no node deletions).

    Unrestricted Δ-sum with PER-KEY attribute merging is NOT associative:
    for a=(attr k=X), b=(delete), c=(re-add, k unset),
    (a+b)+c gives k=-1 but a+(b+c) resurrects X — the tombstone is lost
    when b+c merges first.  The paper's Def. 4 merges *whole* node
    components (trivially associative); per-key merging is our deliberate
    deviation (query-time event deltas are partial), and Algorithm 1 only
    ever composes deltas as a LEFT FOLD in chronological order, where the
    semantics are exactly bucket replay (test_events_to_delta_equals_
    bucket_replay + every test_tgi.py snapshot test).  Associativity is
    asserted on the tombstone-free subalgebra; the left-fold contract
    covers the rest.  Recorded in DESIGN.md §10.
    """
    d = draw(deltas())
    d.present = np.where(d.valid, 1, 0).astype(np.int8)
    return d


@given(tombstone_free_deltas(), tombstone_free_deltas(), tombstone_free_deltas())
@settings(max_examples=40, deadline=None)
def test_sum_associative_tombstone_free(a, b, c):
    lhs = delta_sum(delta_sum(a, b), c)
    rhs = delta_sum(a, delta_sum(b, c))
    assert deltas_equal(lhs, rhs)


def test_sum_not_associative_across_tombstones_known_deviation():
    """Pin the counterexample so the deviation stays documented."""
    a = Delta.empty(P, PSIZE, K)
    a.valid[0, 0] = True
    a.present[0, 0] = 1
    a.attrs[0, 0, 0] = 7
    b = Delta.empty(P, PSIZE, K)
    b.valid[0, 0] = True
    b.present[0, 0] = 0  # tombstone
    c = Delta.empty(P, PSIZE, K)
    c.valid[0, 0] = True
    c.present[0, 0] = 1  # re-add, attrs unset
    lhs = delta_sum(delta_sum(a, b), c)  # the Algorithm-1 left fold
    rhs = delta_sum(a, delta_sum(b, c))
    assert lhs.attrs[0, 0, 0] == -1  # left fold: tombstone respected
    assert rhs.attrs[0, 0, 0] == 7  # right grouping resurrects — known
    assert not deltas_equal(lhs, rhs)


@given(deltas())
@settings(max_examples=50, deadline=None)
def test_self_difference_empty(d):
    diff = delta_difference(d, d)
    assert diff.cardinality() == 0


@given(deltas(), deltas())
@settings(max_examples=40, deadline=None)
def test_hierarchy_reconstruction_identity(a, b):
    """The derived-snapshot invariant: child == parent + (child - parent)
    where parent = a ∩ b.  (Paper §4.3b reconstruction.)"""
    parent = delta_intersection(a, b)
    for child in (a, b):
        rebuilt = delta_sum(parent, delta_difference(child, parent))
        assert deltas_equal(rebuilt, child)


@given(deltas(), deltas())
@settings(max_examples=40, deadline=None)
def test_intersection_subset(a, b):
    inter = delta_intersection(a, b)
    assert (inter.valid <= (a.valid & b.valid)).all()
    assert inter.cardinality() <= min(a.cardinality(), b.cardinality())


# ---------------------------------------------------------------------------
# System-level properties on random streams
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31 - 1), st.sampled_from([500, 1200]),
       st.floats(0.01, 0.99))
@settings(max_examples=8, deadline=None)
def test_tgi_snapshot_equals_replay(seed, n_events, frac):
    events = generate(n_events, seed=seed)
    cfg = TGIConfig(n_shards=2, parts_per_shard=2,
                    events_per_span=max(n_events // 3, 64),
                    eventlist_size=64, checkpoints_per_span=3)
    tgi = TGI.build(events, cfg, DeltaStore(m=3, r=1, backend="mem"))
    t0, t1 = events.time_range()
    t = int(t0 + frac * (t1 - t0))
    got = tgi.get_snapshot(t)
    want = naive_state_at(events, t, cfg.n_attrs)
    n = max(len(got.present), len(want.present))
    got.grow(n), want.grow(n)
    assert (got.present == want.present).all()
    assert (got.edge_key == want.edge_key).all()


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_slotmap_is_permutation(seed):
    rng = np.random.RandomState(seed)
    nids = np.unique(rng.randint(0, 10_000, size=rng.randint(1, 500)))
    sm = SlotMap.build(nids, n_parts=4)
    # (pid, slot) pairs are unique and reversible
    pairs = sm.pid.astype(np.int64) * sm.psize + sm.slot
    assert len(np.unique(pairs)) == len(nids)
    rev = sm.reverse()
    assert set(rev[rev >= 0].tolist()) == set(nids.tolist())
    pid, slot, found = sm.lookup(nids)
    assert found.all()
    assert (rev[pid, slot] == nids).all()


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_events_to_delta_equals_bucket_replay(seed):
    """Folding an event bucket as a Delta over any base state == replaying
    the bucket onto that state (Δ event semantics, paper Ex. 1-2)."""
    from repro.core.snapshot import delta_to_graph, overlay_fold

    events = generate(600, seed=seed)
    half_t = int(np.mean(events.time_range()))
    base = naive_state_at(events, half_t)
    rest = events.take(np.nonzero(events.t > half_t)[0])
    if not len(rest):
        return
    nids = np.unique(np.concatenate([
        base.node_ids(), rest.src, rest.dst[rest.dst >= 0]]))
    nids = nids[nids >= 0]
    sm = SlotMap.build(nids, n_parts=4)
    d_base = base.to_delta(sm, 4)
    d_ev = events_to_delta(rest, sm, 4)
    got = delta_to_graph(overlay_fold([d_base, d_ev]), sm)
    want = base.copy()
    # replay timestamp-at-a-time
    bounds = np.r_[0, np.nonzero(np.diff(rest.t))[0] + 1, len(rest)]
    for i in range(len(bounds) - 1):
        want.apply_bucket(rest.take(slice(int(bounds[i]), int(bounds[i + 1]))))
    n = max(len(got.present), len(want.present))
    got.grow(n), want.grow(n)
    assert (got.present == want.present).all()
    assert (got.edge_key == want.edge_key).all()
