"""Whole-plan compilation (repro.taf.compile): randomized fused-vs-staged
parity over adversarial operands, compile-cache no-retrace guarantees,
fallback coverage notes, the aggregate sum/std extension, and the
style="kernel" device-operand cache."""
import numpy as np
import pytest

from repro.taf import TemporalQuery, compile as tc, replay
from repro.taf.plan import PlanExecutor

from tests.test_replay import random_sots


def _both(q):
    """Run one query fused and staged; returns (fused, staged) results."""
    fused = q.run()
    with tc.disabled():
        staged = q.run()
    return fused, staged


def _ts(rng, t_max=40, T=20):
    return np.sort(rng.randint(0, t_max + 1, size=T)).astype(np.int64)


# ---------------------------------------------------------------------------
# Randomized parity: fused == staged
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_fused_slice_bit_identical_randomized(seed):
    rng = np.random.RandomState(seed)
    sots = random_sots(rng, N=rng.randint(3, 14))
    ts = _ts(rng, T=rng.randint(tc.MIN_FUSE_T, 40))
    fused, staged = _both(TemporalQuery.over(sots).timeslice(list(ts)))
    assert any("fused slice" in n for n in fused.notes), fused.notes
    np.testing.assert_array_equal(fused.value["present"],
                                  staged.value["present"])
    np.testing.assert_array_equal(fused.value["attrs"], staged.value["attrs"])
    assert fused.value["present"].dtype == staged.value["present"].dtype
    assert fused.value["attrs"].dtype == staged.value["attrs"].dtype


@pytest.mark.parametrize("seed", range(4))
def test_fused_pagerank_matches_staged_randomized(seed):
    """Float op: identical math, f32 device vs f64 host — documented
    tolerance (docs/api.md), not bit parity."""
    rng = np.random.RandomState(100 + seed)
    sots = random_sots(rng, N=rng.randint(4, 12))
    ts = _ts(rng, T=18)
    q = TemporalQuery.over(sots).node_compute(
        tc.pagerank(iters=8), style="temporal", points=ts)
    fused, staged = _both(q)
    assert any("fused compute[pagerank]" in n for n in fused.notes)
    np.testing.assert_allclose(fused.value[1], staged.value[1],
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("seed", range(4))
def test_fused_components_bit_identical_randomized(seed):
    rng = np.random.RandomState(200 + seed)
    sots = random_sots(rng, N=rng.randint(4, 12))
    ts = _ts(rng, T=18)
    q = TemporalQuery.over(sots).node_compute(
        tc.components(iters=12), style="temporal", points=ts)
    fused, staged = _both(q)
    assert any("fused compute[components]" in n for n in fused.notes)
    np.testing.assert_array_equal(fused.value[1], staged.value[1])


@pytest.mark.parametrize("seed", range(4))
def test_fused_triangles_bit_identical_randomized(seed):
    rng = np.random.RandomState(300 + seed)
    sots = random_sots(rng, N=rng.randint(4, 12))
    ts = _ts(rng, T=18)
    q = TemporalQuery.over(sots).node_compute(
        tc.triangles(), style="temporal", points=ts)
    fused, staged = _both(q)
    assert any("fused compute[triangles]" in n for n in fused.notes)
    np.testing.assert_array_equal(fused.value[1], staged.value[1])


@pytest.mark.parametrize("mk,exact", [
    (lambda: tc.triangle_count(), True),
    (lambda: tc.component_count(iters=12), True),
    (lambda: tc.max_pagerank(iters=8), False),
])
def test_fused_evolution_matches_staged(mk, exact):
    rng = np.random.RandomState(7)
    sots = random_sots(rng, N=10)
    ts = _ts(rng, T=18)
    fused, staged = _both(TemporalQuery.over(sots).evolution(mk(), points=ts))
    assert any("fused evolution" in n for n in fused.notes), fused.notes
    got, want = np.asarray(fused.value[1]), np.asarray(staged.value[1])
    if exact:
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_fused_after_select_matches_staged():
    """Select runs staged (host), the terminal stage still fuses over the
    filtered operand."""
    rng = np.random.RandomState(8)
    sots = random_sots(rng, N=12)
    ts = _ts(rng, T=18)
    q = (TemporalQuery.over(sots)
         .filter(lambda s: s.node_ids % 2 == 0)
         .node_compute(tc.components(iters=12), style="temporal", points=ts))
    fused, staged = _both(q)
    assert any("fused compute" in n for n in fused.notes)
    np.testing.assert_array_equal(fused.value[1], staged.value[1])


def test_fused_aggregate_epilogue_matches_staged():
    """Aggregate is a host epilogue over the device series: fused and
    staged agree for every per-node reduction incl. the new sum/std."""
    rng = np.random.RandomState(9)
    sots = random_sots(rng, N=10)
    ts = _ts(rng, T=18)
    for op in ("max", "min", "mean", "sum", "std"):
        q = (TemporalQuery.over(sots)
             .node_compute(tc.components(iters=12), style="temporal",
                           points=ts)
             .aggregate(op))
        fused, staged = _both(q)
        np.testing.assert_array_equal(np.asarray(fused.value),
                                      np.asarray(staged.value))


# ---------------------------------------------------------------------------
# Compile cache: zero re-trace on repeated shapes
# ---------------------------------------------------------------------------


def test_repeated_plan_shape_hits_compile_cache():
    rng = np.random.RandomState(10)
    sots = random_sots(rng, N=10)
    ts = _ts(rng, T=20)
    q = TemporalQuery.over(sots).node_compute(
        tc.pagerank(iters=6), style="temporal", points=ts)
    first = q.run()
    traces0 = tc.STATS["traces"]
    # same shape, shifted timepoint *values*: no re-trace, cache hit note
    ts2 = np.minimum(ts + 1, sots.t1).astype(np.int64)
    q2 = TemporalQuery.over(sots).node_compute(
        tc.pagerank(iters=6), style="temporal", points=ts2)
    second = q2.run()
    assert tc.STATS["traces"] == traces0
    assert any("cache hit" in n for n in second.notes), second.notes
    assert any("traced" in n for n in first.notes), first.notes


def test_repeated_fused_slice_rides_replay_lru():
    """A fused slice lands in the executor's replay LRU under the staged
    key: the second identical slice dispatches nothing."""
    rng = np.random.RandomState(11)
    sots = random_sots(rng, N=10)
    ts = _ts(rng, T=20)
    q = TemporalQuery.over(sots).timeslice(list(ts))
    q.run()
    runs0 = tc.STATS["fused_runs"]
    second = q.run()
    assert any("replay-LRU hit" in n for n in second.notes), second.notes
    assert tc.STATS["fused_runs"] == runs0  # served from the LRU


# ---------------------------------------------------------------------------
# Fallback coverage: uncovered shapes run staged, with the reason noted
# ---------------------------------------------------------------------------


def test_small_T_slice_stays_staged_and_counts_replay():
    rng = np.random.RandomState(12)
    sots = random_sots(rng, N=8)
    ts = [3, 9]  # T=2 < MIN_FUSE_T
    before = dict(replay.STATS)
    res = TemporalQuery.over(sots).timeslice(ts).run()
    assert any("staged slice" in n and "MIN_FUSE_T" in n for n in res.notes)
    assert replay.STATS["state_at_many"] == before["state_at_many"] + 1


def test_plain_fn_compute_stays_staged():
    rng = np.random.RandomState(13)
    sots = random_sots(rng, N=8)

    def mean_attr(present, attrs, son, i, t):
        return float(attrs[0])

    res = TemporalQuery.over(sots).node_compute(
        mean_attr, style="temporal", points=[1, 2, 3]).run()
    assert any("staged compute" in n and "not a FusedOp" in n
               for n in res.notes), res.notes


def test_fused_op_is_a_valid_staged_fn():
    """The FusedOp object itself runs on the staged path when fusion is
    off — it IS a vectorized temporal fn (what the parity tests rely on)."""
    rng = np.random.RandomState(14)
    sots = random_sots(rng, N=8)
    with tc.disabled():
        res = TemporalQuery.over(sots).node_compute(
            tc.triangles(), style="temporal", points=[1, 5, 9]).run()
    assert any("fusion disabled" in n for n in res.notes)
    ts_out, series = res.value
    assert series.shape == (8, 3)


# ---------------------------------------------------------------------------
# Aggregate satellite: sum/std per-node reductions
# ---------------------------------------------------------------------------


def test_aggregate_sum_std_per_node_series():
    series = np.arange(12, dtype=np.float64).reshape(3, 4)
    value = (np.arange(4), series)
    np.testing.assert_allclose(
        PlanExecutor._aggregate(value, "sum"), series.sum(axis=1))
    np.testing.assert_allclose(
        PlanExecutor._aggregate(value, "std"), series.std(axis=1))
    with pytest.raises(ValueError):
        PlanExecutor._aggregate(value, "peak")


# ---------------------------------------------------------------------------
# exec satellite: device-resident operands for style="kernel"
# ---------------------------------------------------------------------------


def test_sharded_compute_memoizes_device_operands():
    from repro.taf import exec as taf_exec

    rng = np.random.RandomState(15)
    sots = random_sots(rng, N=9)
    ts = tuple(range(0, 12, 3))
    before = dict(taf_exec.STATS)
    d1 = taf_exec.sharded_degree_series(sots, ts)
    mid = dict(taf_exec.STATS)
    d2 = taf_exec.sharded_degree_series(sots, ts)
    after = dict(taf_exec.STATS)
    np.testing.assert_array_equal(d1, d2)
    # sharded_degree_series patches init_attrs -> a fresh operand per
    # call, so each run transfers once; re-running the SAME operand hits
    son = sots
    k = taf_exec.degree_at_kernel(5)
    # bake degree column the way the helpers do
    import dataclasses as dc

    deg0 = (son.adj_indptr[1:] - son.adj_indptr[:-1]).astype(np.int32)
    patched = dc.replace(
        son, init_attrs=np.concatenate([son.init_attrs, deg0[:, None]], 1))
    taf_exec.sharded_node_compute(patched, k)
    base = taf_exec.STATS["operand_cache_hits"]
    taf_exec.sharded_node_compute(patched, k)
    assert taf_exec.STATS["operand_cache_hits"] == base + 1
    assert after["operand_transfers"] >= mid["operand_transfers"] >= \
        before["operand_transfers"]


def test_kernel_compile_key_shares_jitted_program():
    from repro.taf import exec as taf_exec

    k1 = taf_exec.degree_series_kernel([1, 2, 3])
    k2 = taf_exec.degree_series_kernel([1, 2, 3])
    assert k1 is not k2 and k1.compile_key == k2.compile_key
    assert taf_exec.degree_at_kernel(7).compile_key == ("degree_at", 7)
