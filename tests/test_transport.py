"""Pipelined wire transport: the per-node connection multiplexer
(out-of-order completion fuzzed against a scripted stub peer,
interleaved CHUNK streams, bounded in-flight window backpressure,
enqueue-anchored deadlines, deadline-cancel without connection
poisoning, idle-TTL reaping, HELLO once per connection, loud write
failures vs idempotent retry), server-side head-of-line isolation
(PING stays fast while big GETs saturate the worker pool), SIGKILL
mid-pipeline draining every future, and ack-watermark feed truncation
(bounded feeds under churn, checkpoint boot, byte-identical restart
convergence past a truncation, full-state bootstrap of a wiped cell,
typed FeedTruncated for mem-backed cells)."""
import hashlib
import socket
import struct
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.service import ClusterSpec, FeedTruncated, LocalCluster, StorageCell
from repro.service import wire
from repro.service.client import RemoteDeltaStore
from repro.storage.kvstore import (DeltaKey, DeltaStore, NodeUnavailable,
                                   StorageNodeDown, make_vseq)

HOST = "127.0.0.1"


# ---------------------------------------------------------------------------
# scripted stub peer: speaks the wire protocol, misbehaves on command
# ---------------------------------------------------------------------------


class StubCell:
    """A wire-speaking peer whose reply behavior is scripted per test:
    HELLO and PING are answered inline (so ``RemoteDeltaStore`` can
    attach), everything else goes through ``handler(stub, conn, send,
    frame)`` — which may reply out of order, interleave streams, stall,
    or hang up.  Counts connections, HELLOs, and every received frame."""

    def __init__(self, handler=None):
        self.handler = handler
        self.lsock = socket.socket()
        self.lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.lsock.bind((HOST, 0))
        self.lsock.listen(16)
        self.port = self.lsock.getsockname()[1]
        self.conns = 0
        self.hellos = 0
        self.frames = []  # (msg_type, req_id, body)
        self.lock = threading.Lock()
        self._stop = threading.Event()
        threading.Thread(target=self._accept, daemon=True).start()

    @property
    def addr(self):
        return (HOST, self.port)

    def count(self, mtype):
        with self.lock:
            return sum(1 for t, _, _ in self.frames if t == mtype)

    def close(self):
        self._stop.set()
        try:
            self.lsock.close()
        except OSError:
            pass

    def _accept(self):
        while not self._stop.is_set():
            try:
                conn, _ = self.lsock.accept()
            except OSError:
                return
            with self.lock:
                self.conns += 1
            threading.Thread(target=self._serve, args=(conn,),
                             daemon=True).start()

    def _serve(self, conn):
        send_lock = threading.Lock()

        def send(mtype, req_id, body=b""):
            with send_lock:
                wire.send_frame(conn, mtype, req_id, body)

        try:
            while True:
                try:
                    frame = wire.recv_frame(conn)
                except (wire.WireError, OSError):
                    return
                with self.lock:
                    self.frames.append((frame.msg_type, frame.req_id,
                                        frame.body))
                if frame.msg_type == wire.MSG_HELLO:
                    with self.lock:
                        self.hellos += 1
                    send(wire.MSG_HELLO, frame.req_id,
                         struct.pack("<BQ", 0, 0))
                elif frame.msg_type == wire.MSG_PING:
                    send(wire.MSG_OK, frame.req_id, struct.pack("<BQ", 0, 0))
                elif self.handler is not None:
                    self.handler(self, conn, send, frame)
                else:
                    send(wire.MSG_OK, frame.req_id, frame.body)
        finally:
            try:
                conn.close()
            except OSError:
                pass


def _attach_stub(stub, **kw):
    kw.setdefault("timeout", 5.0)
    kw.setdefault("retries", 1)
    kw.setdefault("backoff", 0.02)
    return RemoteDeltaStore([stub.addr], r=1, **kw)


# ---------------------------------------------------------------------------
# multiplexer: out-of-order completion, stream demux, window, deadlines
# ---------------------------------------------------------------------------


@pytest.mark.timeout(60)
def test_mux_demuxes_shuffled_replies_fuzz():
    """8 concurrent requests per round, 10 rounds, replies deliberately
    shuffled by the peer: every caller must still receive exactly ITS
    reply (byte-identical to the oracle), proving req_id demux rather
    than arrival order pairs replies with requests."""
    rng = np.random.RandomState(7)
    pending = []
    lock = threading.Lock()

    def handler(stub, conn, send, frame):
        with lock:
            pending.append(frame)
            if len(pending) < 8:
                return
            batch, pending[:] = list(pending), []
            order = rng.permutation(len(batch))
        for i in order:
            f = batch[i]
            send(wire.MSG_OK, f.req_id, hashlib.sha256(f.body).digest())

    stub = StubCell(handler)
    store = _attach_stub(stub)
    try:
        barrier = threading.Barrier(8)
        errors = []

        def worker(wid):
            try:
                for rnd in range(10):
                    body = f"req {wid}/{rnd}".encode() * (wid + 1)
                    barrier.wait(timeout=20)
                    reply = store._request(0, wire.MSG_GET, body)
                    assert reply == hashlib.sha256(body).digest()
            except Exception as e:  # noqa: BLE001 — surface in main thread
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(w,))
                   for w in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        assert not errors, errors
        assert stub.hellos == 1  # HELLO exactly once per connection
        assert stub.conns == 1  # one socket carried all 80 requests
        ts = store.transport_stats()
        assert ts["inflight_hwm"] > 1  # genuinely pipelined
        assert ts["rt_pipelined"] > 0
        assert ts["rt_reconnects"] == 0
    finally:
        store.close()
        stub.close()


@pytest.mark.timeout(60)
def test_interleaved_chunk_streams_demux_to_their_futures():
    """Two in-flight MULTIGET streams whose CHUNK frames the peer
    interleaves frame-by-frame: each drain must collect exactly its own
    keys/blobs, byte-identical, with both ENDs honored."""
    pend = []
    lock = threading.Lock()

    def handler(stub, conn, send, frame):
        with lock:
            pend.append(frame)
            if len(pend) < 2:
                return
            a, b = pend
            pend[:] = []
        for i in range(3):  # A1 B1 A2 B2 A3 B3, then END B, END A
            for tag, f in (("A", a), ("B", b)):
                k = DeltaKey(0, 0, f"{tag}:{i}", i)
                send(wire.MSG_CHUNK, f.req_id,
                     wire.pack_key(k) + wire.pack_blob(
                         f"{tag}-blob-{i}".encode() * 5))
        send(wire.MSG_END, b.req_id, struct.pack("<I", 3))
        send(wire.MSG_END, a.req_id, struct.pack("<I", 3))

    stub = StubCell(handler)
    store = _attach_stub(stub)
    try:
        deadline = time.monotonic() + 10
        futs = [store._muxes[0].submit(wire.MSG_MULTIGET, b"ignored",
                                       deadline) for _ in range(2)]
        got = [{}, {}]
        counts = [None, None]

        def drain(i):
            counts[i] = store._mg_drain(0, futs[i], deadline,
                                        lambda k, blob: got[i].update(
                                            {k: blob}))

        threads = [threading.Thread(target=drain, args=(i,))
                   for i in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=20)
        assert counts == [3, 3]
        # submission order == stub's pend order (same socket, FIFO), so
        # futs[0] is stream A.  Each stream got only its own blobs.
        for i, tag in enumerate(("A", "B")):
            assert set(got[i]) == {DeltaKey(0, 0, f"{tag}:{j}", j)
                                   for j in range(3)}
            for j in range(3):
                assert got[i][DeltaKey(0, 0, f"{tag}:{j}", j)] == \
                    f"{tag}-blob-{j}".encode() * 5
    finally:
        store.close()
        stub.close()


@pytest.mark.timeout(60)
def test_window_backpressure_caps_in_flight():
    """window=2: a third concurrent request must NOT reach the wire
    until one of the first two completes — the submitter blocks in the
    window, which is the client half of flow control."""
    release = threading.Event()

    def handler(stub, conn, send, frame):
        def later(f=frame):
            release.wait(timeout=20)
            send(wire.MSG_OK, f.req_id, f.body)
        threading.Thread(target=later, daemon=True).start()

    stub = StubCell(handler)
    store = _attach_stub(stub, window=2)
    try:
        results = []
        threads = [threading.Thread(
            target=lambda i=i: results.append(
                store._request(0, wire.MSG_GET, b"r%d" % i)))
            for i in range(3)]
        for t in threads:
            t.start()
        time.sleep(0.4)
        assert stub.count(wire.MSG_GET) == 2  # third held by the window
        assert store.transport_stats()["in_flight"] == 2
        release.set()
        for t in threads:
            t.join(timeout=20)
        assert len(results) == 3
        assert store.transport_stats()["inflight_hwm"] == 2
    finally:
        store.close()
        stub.close()


@pytest.mark.timeout(60)
def test_deadline_wall_clock_from_enqueue_not_checkout():
    """window=1 and a peer that sits on request A: request B's deadline
    must expire ~timeout after B was *submitted*, even though B never
    got a window slot — the budget starts at enqueue, not at dispatch."""
    def handler(stub, conn, send, frame):
        def later(f=frame):
            time.sleep(1.5)
            try:
                send(wire.MSG_OK, f.req_id, f.body)
            except OSError:
                pass
        threading.Thread(target=later, daemon=True).start()

    stub = StubCell(handler)
    store = _attach_stub(stub, window=1, timeout=0.5)
    try:
        started = threading.Event()

        def occupant():
            started.set()
            with pytest.raises(NodeUnavailable):
                store._request(0, wire.MSG_GET, b"A")

        t = threading.Thread(target=occupant)
        t.start()
        started.wait()
        time.sleep(0.05)  # let A take the slot
        t0 = time.monotonic()
        with pytest.raises(NodeUnavailable):
            store._request(0, wire.MSG_GET, b"B")
        elapsed = time.monotonic() - t0
        t.join(timeout=10)
        assert 0.3 < elapsed < 1.2, elapsed  # ~its own 0.5s, not 1.5s+
        assert stub.count(wire.MSG_GET) == 1  # B never reached the wire
    finally:
        store.close()
        stub.close()


@pytest.mark.timeout(60)
def test_deadline_cancel_leaves_connection_usable():
    """A request that times out must cancel its future WITHOUT
    poisoning the connection: the late reply is drained and dropped,
    and the very same socket serves the next request — no reconnect,
    no second HELLO."""
    first = threading.Event()

    def handler(stub, conn, send, frame):
        if not first.is_set():
            first.set()
            time.sleep(0.8)  # reply late: client gave up at 0.3
        send(wire.MSG_OK, frame.req_id, frame.body)

    stub = StubCell(handler)
    store = _attach_stub(stub, timeout=0.3)
    try:
        with pytest.raises(NodeUnavailable):
            store._request(0, wire.MSG_GET, b"slow")
        assert store.stats.rt_deadline_cancels == 1
        time.sleep(0.8)  # late reply lands, reader drains + drops it
        store.timeout = 5.0
        reply = store._request(0, wire.MSG_GET, b"follow-up")
        assert reply == b"follow-up"
        assert stub.conns == 1 and stub.hellos == 1
        assert store.transport_stats()["rt_reconnects"] == 0
    finally:
        store.close()
        stub.close()


@pytest.mark.timeout(60)
def test_idle_ttl_reaps_mux_connection():
    stub = StubCell()
    store = _attach_stub(stub, idle_ttl=0.3)
    try:
        assert store._request(0, wire.MSG_GET, b"x") == b"x"
        assert store._muxes[0].sock is not None
        time.sleep(1.0)  # reaper interval is idle_ttl/2
        assert store._muxes[0].sock is None  # reaped
        assert store._request(0, wire.MSG_GET, b"y") == b"y"  # re-dialed
        assert stub.conns == 2 and stub.hellos == 2
    finally:
        store.close()
        stub.close()


@pytest.mark.timeout(60)
def test_reconnect_retries_idempotent_but_not_writes():
    """A connection the peer kills mid-request: a GET is transparently
    re-issued on a fresh connection; a PUT gets exactly ONE transport
    attempt and fails loudly (StorageNodeDown; nothing queued, nothing
    silently replayed)."""
    drop_next = {"get": True, "put": True}

    def handler(stub, conn, send, frame):
        if frame.msg_type == wire.MSG_GET and drop_next["get"]:
            drop_next["get"] = False
            conn.close()
            return
        if frame.msg_type == wire.MSG_PUT and drop_next["put"]:
            drop_next["put"] = False
            conn.close()
            return
        send(wire.MSG_OK, frame.req_id, frame.body)

    stub = StubCell(handler)
    store = _attach_stub(stub, retries=2)
    try:
        assert store._request(0, wire.MSG_GET, b"idem") == b"idem"
        assert stub.count(wire.MSG_GET) == 2  # dropped once, retried once
        assert store.transport_stats()["rt_reconnects"] >= 1
        with pytest.raises(StorageNodeDown):
            store.put_encoded(DeltaKey(0, 0, "E:0", 0), b"payload", 7)
        assert stub.count(wire.MSG_PUT) == 1  # ONE attempt, no replay
        assert all(not q for q in store._pending)  # failed != queued
    finally:
        store.close()
        stub.close()


# ---------------------------------------------------------------------------
# server: head-of-line isolation, SIGKILL mid-pipeline
# ---------------------------------------------------------------------------


@pytest.mark.timeout(120)
def test_ping_not_hol_blocked_by_slow_gets(tmp_path):
    """workers=1, the worker pinned inside a slow GET and a second GET
    queued behind it: PINGs on the SAME multiplexed connection must
    keep completing fast, because the cell answers liveness inline on
    its read loop instead of queueing it behind the worker pool.  (In
    the pre-pipelining protocol this exact shape head-of-line-blocked:
    one connection, one outstanding request at a time.)"""
    cell = StorageCell(node_id=0, n_cells=1, r=1, backend="file",
                       root=str(tmp_path / "cell0"), workers=1)
    cell.start()
    store = RemoteDeltaStore([(HOST, cell.port)], r=1, timeout=30.0)
    try:
        key = DeltaKey(0, 0, "E:0", 0)
        store.put(key, {"v": np.arange(100, dtype=np.int64)})
        gate = threading.Event()
        entered = threading.Event()
        real = cell.store.get_encoded

        def slow_get(k, fields=None):
            entered.set()
            gate.wait(timeout=60)  # pin the (only) worker until released
            return real(k, fields)

        cell.store.get_encoded = slow_get
        body = wire.pack_key(key) + wire.pack_fields(None)
        done = []

        def get():
            store._request(0, wire.MSG_GET, body)
            done.append(1)

        threads = [threading.Thread(target=get) for _ in range(2)]
        for t in threads:
            t.start()
        assert entered.wait(timeout=20)  # worker provably busy; GET #2
        lat = []                         # is queued behind it
        for _ in range(30):
            t0 = time.monotonic()
            store._request(0, wire.MSG_PING, b"", retries=0)
            lat.append(time.monotonic() - t0)
        assert not done  # both GETs still in flight: pings overtook them
        gate.set()
        for t in threads:
            t.join(timeout=60)
        assert len(done) == 2  # the slow work itself completed
        assert max(lat) < 1.0, max(lat)  # no ping waited on a GET
    finally:
        store.close()
        cell.stop()


@pytest.mark.timeout(120)
def test_sigkill_mid_pipeline_drains_all_futures(tmp_path):
    """SIGKILL a cell while 8 threads have pipelined multigets in
    flight against it: every future must complete — served by the
    surviving replica via failover, zero failed queries, no hang."""
    spec = ClusterSpec(n_cells=3, r=2, backend="file",
                       root=str(tmp_path / "cluster"))
    with LocalCluster(spec, mode="subprocess") as cl:
        oracle = cl.client(timeout=5.0, pipeline=False)
        rng = np.random.RandomState(3)
        keys = [DeltaKey(t, s, "E:0", p) for t in range(4)
                for s in range(3) for p in range(2)]
        for k in keys:
            oracle.put(k, {"t": np.arange(150, dtype=np.int64) * (k.tsid + 1),
                           "v": rng.randn(150).astype(np.float32)})
        oracle.clear_pool()
        want = oracle.multiget(keys)  # serial-transport oracle
        store = cl.client(timeout=2.0, retries=1, backoff=0.02,
                          suspect_ttl=0.5)
        errors, results = [], []
        killed = threading.Event()

        def reader():
            try:
                for _ in range(3):
                    store.clear_pool()
                    results.append(store.multiget(keys))
                killed.wait(timeout=60)  # rounds guaranteed post-kill
                for _ in range(3):
                    store.clear_pool()
                    results.append(store.multiget(keys))
            except Exception as e:  # noqa: BLE001 — any failure fails the test
                errors.append(e)

        threads = [threading.Thread(target=reader) for _ in range(8)]
        for t in threads:
            t.start()
        while len(results) < 8:  # at least one round per thread in flight
            time.sleep(0.01)
        cl.kill(0)  # SIGKILL mid-pipeline
        killed.set()
        for t in threads:
            t.join(timeout=90)
        assert not errors, errors
        assert len(results) == 48  # 8 threads x 6 rounds, zero failed
        for out in results:  # byte-for-byte what the serial oracle read
            assert set(out) == set(want)
            for k in keys:
                for f in ("t", "v"):
                    assert np.array_equal(out[k][f], want[k][f])
        assert store.transport_stats()["inflight_hwm"] > 1
        assert store.stats.failovers > 0  # the kill was actually absorbed
        oracle.close()
        store.close()


# ---------------------------------------------------------------------------
# feed truncation: bounded feeds, checkpoint boot, convergence, bootstrap
# ---------------------------------------------------------------------------


def _mini_fill(store, n=40, size=50):
    rng = np.random.RandomState(9)
    keys = [DeltaKey(i % 4, i % 3, "E:0", i % 2) for i in range(n)]
    for i, k in enumerate(keys):
        store.put(k, {"t": np.arange(size, dtype=np.int64) + i,
                      "v": rng.randn(size).astype(np.float32)})
    return keys


@pytest.mark.timeout(120)
def test_feed_truncation_bounded_under_churn_and_boot_floor(tmp_path):
    """Writes piggyback the client's ack watermark, so cells truncate
    their feeds while the workload runs (no quiesce needed); a cluster
    restart then boots from feed.base + the truncated log and serves
    every key."""
    spec = ClusterSpec(n_cells=3, r=2, backend="file",
                       root=str(tmp_path / "cluster"), feed_keep=8)
    with LocalCluster(spec, mode="thread") as cl:
        store = cl.client(timeout=5.0)
        keys = _mini_fill(store, n=60)
        feeds = store.feed_status()
        assert all(f is not None for f in feeds)
        assert sum(f["truncations"] for f in feeds) >= 3  # live truncation
        for f in feeds:
            assert f["floor"] > 0
            assert f["len"] < 60  # bounded: far fewer than records hosted
        store.clear_pool()
        want = {k: store.get(k) for k in keys}
        store.close()
    for node in range(3):
        assert (tmp_path / "cluster" / f"cell{node}" / "feed.base").exists()
    with LocalCluster(spec, mode="thread") as cl:  # reboot from checkpoint
        store = cl.client(timeout=5.0)
        for k in set(keys):
            got = store.get(k)
            for f in ("t", "v"):
                assert np.array_equal(got[f], want[k][f])
        # a rebooted writer acquires a FRESH epoch lane above the sealed
        # one — re-stamping seqs below the old floor is impossible by
        # construction, and its watermark lands above every old lane
        store.put(keys[0], want[keys[0]])
        assert store.lease_status()["epoch"] >= 2
        assert store.quiesce() > make_vseq(1, 0)
        store.close()


@pytest.mark.timeout(180)
def test_truncated_restart_catch_up_converges_byte_identical(tmp_path):
    """The PR-6 byte-identity guarantee survives feed truncation: kill
    a cell, keep writing (truncation keeps running on the survivors),
    restart it, quiesce to the common watermark + forced truncation —
    cell 0's chunk, extent, checkpoint AND feed files are byte-for-byte
    what a never-killed run produces."""

    def run(root, kill):
        spec = ClusterSpec(n_cells=3, r=2, backend="file", root=str(root),
                           feed_keep=4)
        with LocalCluster(spec, mode="subprocess") as cl:
            store = cl.client(timeout=2.0, retries=1, backoff=0.02,
                              suspect_ttl=0.2)
            rng = np.random.RandomState(5)
            keys = [DeltaKey(t, s, "E:0", p) for t in range(4)
                    for s in range(3) for p in range(2)]
            half = len(keys) // 2
            for k in keys[:half]:
                store.put(k, {"t": np.arange(100, dtype=np.int64),
                              "v": rng.randn(100).astype(np.float32)})
            if kill:
                cl.kill(0)
            for k in keys[half:]:  # cell 0 misses its share of these
                store.put(k, {"t": np.arange(100, dtype=np.int64),
                              "v": rng.randn(100).astype(np.float32)})
            store.delete(keys[1])
            if kill:
                cl.restart(0)
            store.clear_pool()
            store._suspects.clear()
            for k in keys:
                if k == keys[1]:
                    continue
                assert "t" in store.get(k)
            # drive every cell to the common final feed state
            water = store.quiesce(truncate=True)
            assert water == make_vseq(store.lease_status()["epoch"],
                                      store._seq)
            feeds = store.feed_status()
            assert all(f is not None and f["floor"] == water for f in feeds)
            if kill:  # truncation actually happened during/after churn
                assert sum(f["truncations"] for f in feeds) >= 1
            store.close()
        return {
            str(p.relative_to(root)): hashlib.sha256(p.read_bytes()).hexdigest()
            for p in sorted(Path(root, "cell0").rglob("*")) if p.is_file()
        }

    baseline = run(tmp_path / "a", kill=False)
    recovered = run(tmp_path / "b", kill=True)
    assert baseline == recovered
    assert "cell0/feed.base" in baseline  # the checkpoint is part of it
    assert any(f.endswith(".tgi") for f in baseline)


@pytest.mark.timeout(180)
def test_wiped_cell_bootstraps_by_full_state_transfer(tmp_path):
    """A cell that lost its disk AND faces peers whose feeds are
    truncated below its needs can't replay history — it must pull
    chunk/extent state verbatim from live replicas, landing on byte-
    identical files, then serve reads."""
    spec = ClusterSpec(n_cells=3, r=2, backend="file",
                       root=str(tmp_path / "cluster"), feed_keep=4)
    with LocalCluster(spec, mode="subprocess") as cl:
        store = cl.client(timeout=2.0, retries=1, backoff=0.02,
                          suspect_ttl=0.2)
        keys = _mini_fill(store, n=30)
        water = store.quiesce(truncate=True)
        assert water > 0  # peers' feeds are truncated: replay impossible
        cell1 = Path(tmp_path / "cluster" / "cell1")

        def state_hashes():
            return {str(p.relative_to(cell1)):
                    hashlib.sha256(p.read_bytes()).hexdigest()
                    for p in sorted(cell1.rglob("*"))
                    if p.is_file() and (p.suffix in (".tgi", ".tgx")
                                        or p.name == "feed.base")}

        before = state_hashes()
        assert before  # it held real state
        cl.kill(1)
        cl.wipe(1)  # disk loss: no feed, no checkpoint, no chunks
        assert not cell1.exists()
        cl.restart(1)  # READY implies boot catch-up (bootstrap) finished
        assert state_hashes() == before  # verbatim full-state transfer
        status = store.cell_status(1)
        assert status["feed"]["floor"] == water  # adopted the peer floor
        assert status["n_keys"] > 0  # accounting restored, not just bytes
        store.clear_pool()
        store._suspects.clear()
        for k in set(keys):  # and the cluster serves everything
            assert "t" in store.get(k)
        store.close()


@pytest.mark.timeout(60)
def test_mem_cell_raises_typed_feed_truncated(tmp_path):
    """The file backend can full-state-transfer past a truncation; the
    mem backend cannot — a fresh mem cell facing a truncated peer must
    fail with the typed FeedTruncated (and serve ERR_FEED_TRUNCATED on
    the wire), never converge silently incomplete."""
    a = StorageCell(node_id=0, n_cells=2, r=2, backend="mem", feed_keep=1)
    a.start()
    try:
        blob = DeltaStore(m=1, r=1, backend="mem").encode_payload(
            DeltaKey(0, 0, "E:0", 0), {"t": np.arange(5, dtype=np.int64)})
        for seq in (1, 2, 3):
            a.apply(wire.FeedRecord(seq, wire.OP_PUT,
                                    DeltaKey(0, 0, "E:0", seq - 1),
                                    40, blob))
        a.note_ack(3)
        assert a._floors.get(0) == 3 and a.truncations == 1
        b = StorageCell(node_id=1, n_cells=2, r=2, backend="mem")
        with pytest.raises(FeedTruncated):
            b.catch_up([(HOST, a.port)])
        # and over the wire: STATE_PULL against a mem cell is typed too
        store = RemoteDeltaStore([(HOST, a.port)], r=1)
        with pytest.raises(wire.RemoteError) as ei:
            store._request(0, wire.MSG_STATE_PULL, struct.pack("<qq", 0, 0))
        assert ei.value.code == wire.ERR_FEED_TRUNCATED
        store.close()
    finally:
        a.stop()


@pytest.mark.timeout(60)
def test_transport_stats_shape_local_vs_remote(tmp_path):
    """Local stores report no transport ({}); the remote store reports
    the mux view cache_stats()/storage_report build on."""
    assert DeltaStore(m=2, r=1, backend="mem").transport_stats() == {}
    spec = ClusterSpec(n_cells=2, r=1, backend="file",
                       root=str(tmp_path / "cluster"))
    with LocalCluster(spec, mode="thread") as cl:
        store = cl.client()
        ts = store.transport_stats()
        for field in ("pipeline", "window", "in_flight", "inflight_hwm",
                      "rt_pipelined", "rt_serial", "rt_deadline_cancels",
                      "rt_reconnects", "nodes"):
            assert field in ts
        assert ts["pipeline"] is True and len(ts["nodes"]) == 2
        snap = store.report_snapshot()
        assert snap["transport"]["window"] == store.window
        assert len(snap["feeds"]) == 2
        store.close()
