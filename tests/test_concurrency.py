"""MVCC concurrency stress + fault-injection suite (the proof for
snapshot-isolated background maintenance).

Three families:

* seeded reader/ingester/compactor schedules — every read taken under a
  ``read_guard()`` must be bit-identical to a single-threaded oracle
  replay of the *view's own* event log (torn reads have nowhere to
  hide: presence, attrs, edges, and histories are all compared),
  while ingest appends and compaction swaps the layout concurrently;
* GC safety — superseded chunks stay readable while any guard pins an
  older epoch, are reclaimed when the last pin drains, and
  ``storage_report()`` stays internally consistent mid-compaction;
* fault injection — a maintenance pass killed at shadow-build,
  pre-swap, post-swap, or mid-GC leaves the store readable and a
  retried pass converges (``repro.core.faultpoints``).

``REPRO_SEED_OFFSET`` shifts every schedule's seed so CI can run the
same suite under genuinely distinct interleavings (the ``stress`` job
runs 3 offsets).
"""
import os
import threading
import time
import traceback

import numpy as np
import pytest

from repro.core import faultpoints
from repro.core.snapshot import GraphState
from repro.core.tgi import TGI, TGIConfig
from repro.data.temporal_graph_gen import generate, naive_state_at
from repro.storage.kvstore import DeltaStore

SEED_OFFSET = int(os.environ.get("REPRO_SEED_OFFSET", "0"))
SCHEDULE_SEEDS = [11, 23, 37, 41, 53, 67, 79, 97]

N_EVENTS = 2400
N_INITIAL = 1200
CFG = dict(n_shards=2, parts_per_shard=2, events_per_span=300,
           eventlist_size=64, checkpoints_per_span=2)


def _states_equal(a: GraphState, b: GraphState, msg=""):
    n = max(len(a.present), len(b.present))
    a.grow(n)
    b.grow(n)
    assert (a.present == b.present).all(), f"presence mismatch {msg}"
    on = a.present == 1
    assert (a.attrs[on] == b.attrs[on]).all(), f"attr mismatch {msg}"
    assert len(a.edge_key) == len(b.edge_key), f"edge count {msg}"
    assert (a.edge_key == b.edge_key).all(), f"edge keys {msg}"
    assert (a.edge_val == b.edge_val).all(), f"edge attrs {msg}"


def _mk(seed: int, store=None):
    """A TGI seeded with an initial bulk build; the remaining events are
    returned for the ingester to stream in as micro-span updates."""
    events = generate(N_EVENTS, seed=seed)
    init = events.take(slice(0, N_INITIAL))
    rest = events.take(slice(N_INITIAL, N_EVENTS))
    cfg = TGIConfig(**CFG)
    tgi = TGI.build(init, cfg,
                    store if store is not None
                    else DeltaStore(m=2, r=1, backend="mem"))
    return tgi, events, rest, cfg


def _view_log(view):
    """The full event log of one pinned view (sealed + streaming
    buffer) — the oracle's input: what ``get_snapshot`` must replay."""
    if len(view.pending):
        return view.events.concat(view.pending)
    return view.events


def _check_snapshot_at(tgi, view, t):
    """One pinned read vs the single-threaded oracle at this epoch."""
    got = tgi.get_snapshot(t)
    want = naive_state_at(_view_log(view), t, tgi.cfg.n_attrs)
    _states_equal(got, want, f"epoch={view.epoch} t={t}")


def _check_history_at(tgi, view, nid, t0, t1):
    """Node history vs a direct filter of the view's own log."""
    full = _view_log(view)
    sel = (((full.src == nid) | (full.dst == nid))
           & (full.t > t0) & (full.t <= t1))
    want = full.take(np.nonzero(sel)[0])
    _, got = tgi.get_node_history(int(nid), int(t0), int(t1))
    assert len(got) == len(want), (
        f"history count nid={nid} epoch={view.epoch}")
    for col in ("t", "kind", "src", "dst", "key", "val"):
        assert (getattr(got, col) == getattr(want, col)).all(), (
            f"history {col} nid={nid} epoch={view.epoch}")


def _reader_loop(tgi, stop, errors, seed):
    rng = np.random.default_rng(seed)
    try:
        while not stop.is_set():
            with tgi.read_guard() as view:
                full = _view_log(view)
                if not len(full):
                    continue
                t0, t1 = full.time_range()
                t = int(rng.integers(t0, t1 + 1))
                _check_snapshot_at(tgi, view, t)
                if rng.random() < 0.3:
                    nid = int(rng.integers(0, max(view.n_nodes, 1)))
                    _check_history_at(tgi, view, nid, t0, t)
    except Exception:  # noqa: BLE001 — surfaced via the errors list
        errors.append(traceback.format_exc())
        stop.set()


def _ingest_loop(tgi, rest, errors, seed, stop):
    rng = np.random.default_rng(seed)
    try:
        lo = 0
        while lo < len(rest) and not stop.is_set():
            hi = min(lo + int(rng.integers(60, 140)), len(rest))
            tgi.update(rest.take(slice(lo, hi)))
            lo = hi
            if rng.random() < 0.5:
                time.sleep(float(rng.random()) * 0.002)
    except Exception:  # noqa: BLE001
        errors.append(traceback.format_exc())
        stop.set()


def _compact_loop(tgi, stop, errors, seed):
    rng = np.random.default_rng(seed)
    try:
        while not stop.is_set():
            tgi.compact(min_run=2)
            time.sleep(float(rng.random()) * 0.005)
    except Exception:  # noqa: BLE001
        errors.append(traceback.format_exc())
        stop.set()


# ---------------------------------------------------------------------------
# Seeded reader/ingester/compactor schedules
# ---------------------------------------------------------------------------


@pytest.mark.timeout(180)
@pytest.mark.parametrize("seed", SCHEDULE_SEEDS)
def test_stress_schedule(seed):
    """Readers, an ingester, and a compactor race freely; every pinned
    read must be bit-identical to the oracle at its epoch."""
    seed = seed + SEED_OFFSET
    tgi, events, rest, cfg = _mk(seed)
    errors: list = []
    stop = threading.Event()
    readers = [
        threading.Thread(target=_reader_loop,
                         args=(tgi, stop, errors, seed * 100 + i),
                         name=f"reader-{i}", daemon=True)
        for i in range(3)
    ]
    ingester = threading.Thread(target=_ingest_loop,
                                args=(tgi, rest, errors, seed * 7, stop),
                                name="ingester", daemon=True)
    compactor = threading.Thread(target=_compact_loop,
                                 args=(tgi, stop, errors, seed * 13),
                                 name="compactor", daemon=True)
    for t in readers + [ingester, compactor]:
        t.start()
    ingester.join(timeout=120)
    time.sleep(0.05)  # let readers observe the final state at least once
    stop.set()
    for t in readers + [compactor]:
        t.join(timeout=30)
    assert not ingester.is_alive(), "ingester wedged"
    assert not errors, "torn/incorrect reads:\n" + "\n".join(errors)
    # quiesced: the final state matches a clean single-threaded replay
    tgi.flush()
    t0, t1 = events.time_range()
    for frac in (0.2, 0.55, 0.9, 1.0):
        t = int(t0 + frac * (t1 - t0))
        _states_equal(tgi.get_snapshot(t),
                      naive_state_at(events, t, cfg.n_attrs), f"final t={t}")
    assert tgi.maintenance_stats["passes"] >= 1
    assert tgi.maintenance_stats["failed_passes"] == 0
    # nothing pinned anymore: the deferred-GC queue must drain fully
    tgi.compact(min_run=2)
    assert tgi.pinned_epochs() == []
    assert tgi.store.gc_pending() == 0


# ---------------------------------------------------------------------------
# GC safety under pinned epochs
# ---------------------------------------------------------------------------


@pytest.mark.timeout(60)
def test_gc_deferred_while_epoch_pinned():
    """A compaction completing inside an open read guard must park the
    superseded keys instead of deleting them: the pinned reader re-reads
    its epoch bit-identically afterwards, and the queue drains only when
    the guard exits."""
    tgi, events, rest, cfg = _mk(5 + SEED_OFFSET)
    for lo in range(0, len(rest), 100):
        tgi.update(rest.take(slice(lo, lo + 100)))
    t0, t1 = events.time_range()
    t = int(t0 + 0.7 * (t1 - t0))
    with tgi.read_guard() as view:
        before = tgi.get_snapshot(t)
        stats = tgi.compact(min_run=2)  # maintenance thread, we stay pinned
        assert stats.runs_merged >= 1
        # superseded keys are queued, not gone — our pin protects them
        assert tgi.store.gc_pending() > 0
        assert tgi.pinned_epochs() == [view.epoch]
        # the pinned epoch re-reads bit-identically THROUGH the swap
        after = tgi.get_snapshot(t)
        _states_equal(before, after, "pinned re-read across publish")
        _states_equal(after, naive_state_at(_view_log(view), t, cfg.n_attrs),
                      "pinned read vs oracle")
    # guard exit = last pin drained = the queue empties
    assert tgi.store.gc_pending() == 0
    assert tgi.pinned_epochs() == []
    # and the published layout serves the same truth
    _states_equal(tgi.get_snapshot(t), naive_state_at(events, t, cfg.n_attrs))


@pytest.mark.timeout(60)
def test_gc_never_reclaims_reachable_keys_under_guard_churn():
    """Guards opening/closing while compaction publishes: at no instant
    may a key a pinned reader can still reach be deleted — proven by the
    readers themselves (any reclaimed-but-reachable chunk would fail
    their bit-identity check with KeyMissing or wrong bytes)."""
    tgi, events, rest, cfg = _mk(29 + SEED_OFFSET)
    errors: list = []
    stop = threading.Event()
    readers = [
        threading.Thread(target=_reader_loop,
                         args=(tgi, stop, errors, 1000 + i), daemon=True)
        for i in range(4)
    ]
    for t in readers:
        t.start()
    try:
        for lo in range(0, len(rest), 80):
            tgi.update(rest.take(slice(lo, lo + 80)))
            if lo % 240 == 0:
                tgi.compact(min_run=2)
    finally:
        time.sleep(0.05)
        stop.set()
        for t in readers:
            t.join(timeout=30)
    assert not errors, "GC broke a pinned reader:\n" + "\n".join(errors)
    tgi.compact(min_run=2)
    assert tgi.store.gc_pending() == 0


@pytest.mark.timeout(90)
def test_storage_report_internally_consistent_mid_compaction():
    """``storage_report()`` sampled while the maintenance thread
    publishes must never mix pre- and post-GC accounting: components,
    totals, and per-node placement all derive from one key-size copy."""
    tgi, events, rest, cfg = _mk(71 + SEED_OFFSET)
    errors: list = []
    stop = threading.Event()

    def sampler():
        try:
            while not stop.is_set():
                rep = tgi.storage_report()
                comp_raw = sum(r["raw"] for r in rep["components"].values())
                comp_enc = sum(r["encoded"]
                               for r in rep["components"].values())
                comp_cnt = sum(r["count"] for r in rep["components"].values())
                assert rep["totals"]["raw"] == comp_raw
                assert rep["totals"]["encoded"] == comp_enc
                assert rep["totals"]["count"] == comp_cnt
                # every key is placed on exactly r nodes, from the SAME
                # key-size copy the totals were computed from
                node_bytes = sum(n["live_bytes"]
                                 for n in rep["nodes"]["nodes"])
                node_keys = sum(n["live_keys"] for n in rep["nodes"]["nodes"])
                assert node_bytes == comp_enc * rep["replication"]
                assert node_keys == comp_cnt * rep["replication"]
                assert rep["gc"]["pending_keys"] >= 0
        except Exception:  # noqa: BLE001
            errors.append(traceback.format_exc())
            stop.set()

    s = threading.Thread(target=sampler, daemon=True)
    s.start()
    try:
        for lo in range(0, len(rest), 60):
            tgi.update(rest.take(slice(lo, lo + 60)))
            if lo % 180 == 0:
                tgi.compact(min_run=2)
    finally:
        stop.set()
        s.join(timeout=30)
    assert not errors, "inconsistent storage_report:\n" + "\n".join(errors)


# ---------------------------------------------------------------------------
# Satellite fix regression: epoch bump + cache invalidation atomicity
# ---------------------------------------------------------------------------


@pytest.mark.timeout(60)
def test_epoch_bump_and_cache_invalidation_atomic():
    """A concurrent observer must never see a bumped ``read_epoch``
    paired with stale cache contents: the epoch, the snapshot LRU purge,
    and the mean-degree refresh all commit under one ``_mvcc`` hold."""
    tgi, events, rest, cfg = _mk(3 + SEED_OFFSET)
    errors: list = []
    stop = threading.Event()

    def observer():
        try:
            while not stop.is_set():
                with tgi._mvcc:
                    epoch = tgi.read_epoch
                    md = tgi._mean_degree_cache
                    assert tgi.read_epoch == epoch  # lock held: stable
                    # the mean-degree cache is either freshly invalidated
                    # or tagged with the CURRENT epoch — a stale tag
                    # alongside a bumped epoch is the torn state the fix
                    # removed
                    assert md is None or md[0] == epoch, (
                        f"stale _mean_degree_cache tag {md[0]} at "
                        f"epoch {epoch}")
                # outside the lock: populate the caches so invalidation
                # has something to race against
                tgi._mean_degree()
        except Exception:  # noqa: BLE001
            errors.append(traceback.format_exc())
            stop.set()

    obs = [threading.Thread(target=observer, daemon=True) for _ in range(2)]
    for o in obs:
        o.start()
    try:
        for lo in range(0, len(rest), 50):
            tgi.update(rest.take(slice(lo, lo + 50)))
            if lo % 200 == 0:
                tgi.compact(min_run=2)
    finally:
        stop.set()
        for o in obs:
            o.join(timeout=30)
    assert not errors, "torn epoch/cache state:\n" + "\n".join(errors)
    # snapshot-LRU entries inserted under an older epoch must never be
    # served after the bump: a fresh read reflects the new events
    tgi.flush()
    t0, t1 = events.time_range()
    _states_equal(tgi.get_snapshot(t1),
                  naive_state_at(events, t1, cfg.n_attrs), "post-churn read")


# ---------------------------------------------------------------------------
# Fault injection: killed maintenance passes
# ---------------------------------------------------------------------------

CRASH_POINTS = ["compact.shadow_build", "compact.pre_swap",
                "compact.post_swap", "compact.mid_gc"]


def _assert_readable(tgi, events, cfg, msg):
    t0, t1 = events.time_range()
    for frac in (0.3, 0.8):
        t = int(t0 + frac * (t1 - t0))
        _states_equal(tgi.get_snapshot(t),
                      naive_state_at(events, t, cfg.n_attrs), f"{msg} t={t}")


@pytest.mark.timeout(120)
@pytest.mark.parametrize("point", CRASH_POINTS)
def test_killed_maintenance_pass_is_safe_and_retry_converges(point):
    """Crash the maintenance pass at each phase: the store stays fully
    readable (no torn layout, no vanished chunk), and a retried pass
    converges to the compacted layout with an empty GC queue."""
    tgi, events, rest, cfg = _mk(47 + SEED_OFFSET)
    for lo in range(0, len(rest), 100):
        tgi.update(rest.take(slice(lo, lo + 100)))
    spans_before = len(tgi.spans)
    with faultpoints.scoped(point):
        with pytest.raises(faultpoints.FaultError):
            tgi.compact(min_run=2)
    assert tgi.maintenance_stats["failed_passes"] == 1
    # whatever phase died, every epoch-visible chunk is still readable
    _assert_readable(tgi, events, cfg, f"after {point} crash")
    # the fired point disarmed itself: the retry runs clean and converges
    stats = tgi.compact(min_run=2)
    assert tgi.maintenance_stats["failed_passes"] == 1  # no new failure
    _assert_readable(tgi, events, cfg, f"after {point} retry")
    assert len(tgi.spans) < spans_before  # the merge actually landed
    assert tgi.store.gc_pending() == 0  # including the interrupted GC
    if point in ("compact.shadow_build", "compact.pre_swap"):
        # pre-publish crash: the retry performed the whole merge itself
        assert stats.runs_merged >= 1


@pytest.mark.timeout(60)
def test_pre_publish_crash_leaves_no_shadow_garbage():
    """A pass killed before the swap must delete its never-published
    shadow chunks — retrying forever must not leak storage."""
    tgi, events, rest, cfg = _mk(59 + SEED_OFFSET)
    for lo in range(0, len(rest), 100):
        tgi.update(rest.take(slice(lo, lo + 100)))
    tgi.flush()
    live_before = tgi.index_size_bytes()
    for _ in range(3):
        with faultpoints.scoped("compact.pre_swap"):
            with pytest.raises(faultpoints.FaultError):
                tgi.compact(min_run=2)
        assert tgi.index_size_bytes() == live_before, "shadow chunks leaked"
    stats = tgi.compact(min_run=2)
    assert stats.runs_merged >= 1
    assert tgi.index_size_bytes() < live_before  # GC finally shrank it


@pytest.mark.timeout(60)
def test_mid_gc_crash_requeues_remainder():
    """A drain killed mid-batch re-queues the undeleted keys; the next
    drain reclaims exactly the remainder (no leak, no double-free)."""
    tgi, events, rest, cfg = _mk(83 + SEED_OFFSET)
    for lo in range(0, len(rest), 100):
        tgi.update(rest.take(slice(lo, lo + 100)))
    # crash on the 3rd GC'd key: some deleted, the rest re-queued
    with faultpoints.scoped("compact.mid_gc", hits=3):
        with pytest.raises(faultpoints.FaultError):
            tgi.compact(min_run=2)
    pending = tgi.store.gc_pending()
    assert pending > 0
    deleted, _ = tgi.store.gc_drain()
    assert deleted == pending
    assert tgi.store.gc_pending() == 0
    _assert_readable(tgi, events, cfg, "after mid-GC crash + drain")


@pytest.mark.timeout(60)
def test_faultpoint_env_parsing_and_scoping():
    """The arming surfaces behave as documented: env parsing, countdown
    + self-disarm, and context-local arming invisible to other threads."""
    assert faultpoints._parse_env("a.b=3:kill, c.d=1") == {
        "a.b": [3, "kill"], "c.d": [1, "raise"]}
    with pytest.raises(ValueError):
        faultpoints._parse_env("a=1:explode")
    # countdown: fires N-1 times silently, acts on the Nth, then disarms
    faultpoints.arm("t.count", hits=3)
    faultpoints.fire("t.count")
    faultpoints.fire("t.count")
    with pytest.raises(faultpoints.FaultError):
        faultpoints.fire("t.count")
    faultpoints.fire("t.count")  # disarmed: clean
    # local(): the arming thread trips it, a worker thread does not
    hit_in_worker = []

    def worker():
        try:
            faultpoints.fire("t.local")
        except faultpoints.FaultError:
            hit_in_worker.append(True)

    with faultpoints.local("t.local"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
        assert not hit_in_worker, "ContextVar arming leaked across threads"
        with pytest.raises(faultpoints.FaultError):
            faultpoints.fire("t.local")
    faultpoints.reset()
