"""Unified query layer: plan structure, fetch pushdown (pruning +
projection), numpy-vs-shard_map parity, and the vectorized timeslice
replay vs its reference loop."""
import numpy as np
import pytest

from repro.data.temporal_graph_gen import generate
from repro.storage.kvstore import DeltaStore
from repro.taf import HistoricalGraphStore, TemporalQuery, operators as ops
from repro.taf.son import build_sots


@pytest.fixture(scope="module")
def setup():
    events = generate(4000, seed=13)
    store = HistoricalGraphStore.build(
        events, n_shards=2, parts_per_shard=2, events_per_span=1200,
        eventlist_size=128, checkpoints_per_span=3,
        store=DeltaStore(m=3, r=1, backend="mem"))
    t0g, t1g = store.time_range()
    t0 = int(t0g + 0.3 * (t1g - t0g))
    t1 = int(t0g + 0.8 * (t1g - t0g))
    return store, t0, t1


# ---------------------------------------------------------------------------
# Plan structure (golden)
# ---------------------------------------------------------------------------


def test_plan_structure_golden(setup):
    store, t0, t1 = setup

    def f(present, attrs, son, i, t):
        return float(present)

    q = (store.nodes(t0, t1)
         .filter(lambda s: s.init_present == 1)
         .khop(1)
         .node_compute(f, style="temporal")
         .aggregate("mean"))
    plan = q.plan()
    assert plan.kinds == ("fetch", "select", "compute", "aggregate")
    assert plan.stages[0].subgraph  # khop(1) became a SoTS fetch
    # standalone timeslice stays a Slice stage ...
    assert store.nodes(t0, t1).timeslice(t0).plan().kinds == ("fetch", "slice")
    # ... but fuses into a following compute's evaluation points
    fused = store.nodes(t0, t1).timeslice(t0).node_compute(f, style="temporal").plan()
    assert fused.kinds == ("fetch", "compute")
    assert list(fused.stages[1].points) == [t0]


def test_plan_validation(setup):
    store, t0, t1 = setup
    with pytest.raises(ValueError):
        store.nodes(t0, t1).aggregate("max").plan()  # aggregate needs a series
    with pytest.raises(ValueError):
        store.nodes(t0, t1).timeslice(t0).aggregate("max").plan()  # dict, not series
    with pytest.raises(ValueError):
        (store.nodes(t0, t1).timeslice(t0)
         .filter(lambda s: s.init_present == 1).plan())  # select after slice
    with pytest.raises(ValueError):
        store.nodes(t0, t1).timeslice(t0).khop(1)  # adjacency is fetch-time


def test_facade_retrieval_cost_accumulates_across_rounds(setup):
    """k_hop 'expand' runs one get_snapshot per frontier round, each of
    which resets tgi.last_cost; the facade must report the whole query."""
    store, t0, t1 = setup
    tm = (t0 + t1) // 2
    g = store.snapshot(tm)
    assert store.last_cost.n_deltas > 0
    hub = int(np.argmax(g.degree()))
    store.k_hop(hub, tm, 2, method="expand")
    assert store.last_cost.n_deltas > store.tgi.last_cost.n_deltas


def test_node_id_filter_pushes_into_fetch(setup):
    store, t0, t1 = setup
    plan = store.nodes(t0, t1).filter(node_ids=[1, 2, 3]).plan()
    assert plan.kinds == ("fetch",)  # absorbed: no residual Select
    assert plan.stages[0].node_ids == (1, 2, 3)
    # a callable filter stays a Select stage
    plan = store.nodes(t0, t1).filter(lambda s: s.init_present == 1).plan()
    assert plan.kinds == ("fetch", "select")


# ---------------------------------------------------------------------------
# Pushdown correctness: pruned fetch == full fetch, strictly cheaper
# ---------------------------------------------------------------------------


def _ids_in_one_partition(store, node_ids, t0):
    """Hash placement spreads arbitrary id sets over every partition, so
    pick the members of a single micro-partition — the selective query a
    pruned fetch is for."""
    si = store.tgi._span_index(t0)
    pid, _, found = si.smap.lookup(node_ids)
    return node_ids[found & (pid == pid[found][0])]


def test_pushdown_pruned_fetch_identical_and_cheaper(setup):
    store, t0, t1 = setup
    full = store.nodes(t0, t1).run()
    ids = _ids_in_one_partition(store, full.operand.node_ids, t0)
    assert len(ids) > 3
    pruned = store.nodes(t0, t1).filter(node_ids=ids).run()

    assert pruned.cost.n_deltas < full.cost.n_deltas
    assert pruned.cost.n_bytes < full.cost.n_bytes

    # identical per-node results on the selected ids
    tm = (t0 + t1) // 2
    pos = np.searchsorted(full.operand.node_ids, ids)
    want = ops.timeslice(full.operand.subset(pos), tm)
    got = store.nodes(t0, t1).filter(node_ids=ids).timeslice(tm).execute()
    assert (got["present"] == want["present"]).all()
    on = want["present"] == 1
    assert (got["attrs"][on] == want["attrs"][on]).all()


def test_pushdown_subgraph_adjacency_exact(setup):
    """Edges are mirrored under both endpoints' slots, so a pruned SoTS
    fetch carries the members' complete initial adjacency."""
    store, t0, t1 = setup
    full = store.subgraphs(t0, t1).run().operand
    ids = _ids_in_one_partition(store, full.node_ids, t0)
    pruned = (store.nodes(t0, t1).filter(node_ids=ids).khop(1)
              .run().operand)
    pos = np.searchsorted(full.node_ids, ids)
    want = full.subset(pos)
    assert (pruned.node_ids == want.node_ids).all()
    for i in range(len(want)):
        nbr_w, _ = want.neighbors_of(i)
        nbr_p, _ = pruned.neighbors_of(i)
        assert set(nbr_w.tolist()) == set(nbr_p.tolist())


def test_pushdown_empty_selection_yields_empty_operand(setup):
    """A node-set filter matching nothing in the t0 span must return an
    empty result, not crash the pruned snapshot path."""
    store, t0, t1 = setup
    missing = int(store.tgi.n_nodes) + 1000
    r = store.nodes(t0, t1).filter(node_ids=[missing]).run()
    assert len(r.operand) == 0


def test_pushdown_matches_post_fetch_select_for_late_born_ids(setup):
    """The pushed-down and post-fetch spellings of a node-set filter must
    return the same rows — ids not alive at t0 are outside the query's
    node universe either way."""
    store, t0, t1 = setup
    universe = set(store.nodes(t0, t1).run().operand.node_ids.tolist())
    # ids that exist in the history but are not alive at t0
    late = [i for i in range(store.tgi.n_nodes) if i not in universe][:3]
    alive = sorted(universe)[:3]
    ids = late + alive
    pushed = store.nodes(t0, t1).filter(node_ids=ids).run().operand
    full = store.nodes(t0, t1).run().operand
    selected = (TemporalQuery.over(full)
                .filter(node_ids=ids)
                .run().operand)
    assert pushed.node_ids.tolist() == selected.node_ids.tolist() == alive


def test_sots_fetch_reads_snapshot_once(setup):
    """build_sots reuses one t0 snapshot for state + adjacency — the SoTS
    fetch must not cost more deltas than the SoN fetch."""
    store, t0, t1 = setup
    son_cost = store.nodes(t0, t1).run().cost
    sots_cost = store.subgraphs(t0, t1).run().cost
    assert sots_cost.n_deltas == son_cost.n_deltas


def test_slice_fusion_rejects_lossy_chains(setup):
    store, t0, t1 = setup

    def f(present, attrs, son, i, t):
        return float(present)

    # multi-point slice cannot silently collapse into a static compute
    with pytest.raises(ValueError):
        store.nodes(t0, t1).timeslice([t0, t1]).node_compute(f, style="static").plan()
    # kernel computes take no evaluation points at all
    with pytest.raises(ValueError):
        store.nodes(t0, t1).timeslice(t0).node_compute(f, style="kernel").plan()
    # multi-point slice into temporal evaluates every point
    ts, vals = (store.nodes(t0, t1).timeslice([t0, t1])
                .node_compute(f, style="temporal").execute())
    assert vals.shape[1] == 2


def test_projection_skips_attr_bytes(setup):
    store, t0, t1 = setup
    tm = (t0 + t1) // 2

    def fv(present, attrs, son=None, t=None, **kw):
        return present.astype(float)

    fv.vectorized = True
    base = store.nodes(t0, t1).node_compute(fv, style="static", t=tm)
    r_full = base.run()
    r_proj = base.project(attrs=False).run()
    np.testing.assert_allclose(r_proj.value, r_full.value)
    assert r_proj.cost.n_bytes < r_full.cost.n_bytes
    assert r_proj.cost.n_deltas == r_full.cost.n_deltas  # same shards read


# ---------------------------------------------------------------------------
# numpy vs shard_map parity on node_compute
# ---------------------------------------------------------------------------


def test_numpy_vs_shard_map_node_compute_parity(setup):
    store, t0, t1 = setup
    import dataclasses

    from repro.taf import exec as taf_exec

    sots = store.subgraphs(t0, t1).materialize().operand
    tm = (t0 + t1) // 2
    deg0 = (sots.adj_indptr[1:] - sots.adj_indptr[:-1]).astype(np.int32)
    patched = dataclasses.replace(
        sots, init_attrs=np.concatenate([sots.init_attrs, deg0[:, None]], 1))
    device = (TemporalQuery.over(patched)
              .node_compute(taf_exec.degree_at_kernel(tm), style="kernel")
              .execute())
    from repro.taf import analytics

    _, host = analytics.degree_series_delta(sots, points=[tm])
    on = sots.init_present == 1
    np.testing.assert_allclose(device[on].astype(float), host[on, 0])


# ---------------------------------------------------------------------------
# Vectorized timeslice replay vs reference loop
# ---------------------------------------------------------------------------


def test_state_at_vectorized_matches_reference(setup):
    store, t0, t1 = setup
    sots = store.subgraphs(t0, t1).materialize().operand
    for t in np.linspace(t0 - 1, t1 + 1, 9).astype(np.int64):
        p_ref, a_ref = ops._state_at_ref(sots, int(t))
        p_vec, a_vec = ops._state_at(sots, int(t))
        assert (p_ref == p_vec).all()
        assert (a_ref == a_vec).all()


def test_state_at_delete_then_rewrite():
    """NODE_DEL clears all attrs; a later NATTR_SET resurrects the node
    with only that key set — the ordering case the lexsort must get right."""
    from repro.core.events import NATTR_SET, NODE_ADD, NODE_DEL
    from repro.taf.son import SoN

    son = SoN(
        node_ids=np.asarray([0, 1], np.int32), t0=0, t1=10,
        init_present=np.asarray([1, 1], np.int8),
        init_attrs=np.asarray([[5, 6], [7, 8]], np.int32),
        ev_indptr=np.asarray([0, 3, 4], np.int64),
        ev_t=np.asarray([1, 2, 3, 2], np.int64),
        ev_kind=np.asarray([NODE_DEL, NATTR_SET, NATTR_SET, NODE_DEL], np.int8),
        ev_key=np.asarray([-1, 0, 0, -1], np.int16),
        ev_val=np.asarray([-1, 9, 11, -1], np.int32),
        ev_other=np.full(4, -1, np.int32),
    )
    for t in (0, 1, 2, 3, 10):
        p_ref, a_ref = ops._state_at_ref(son, t)
        p_vec, a_vec = ops._state_at(son, t)
        assert (p_ref == p_vec).all(), t
        assert (a_ref == a_vec).all(), t


# ---------------------------------------------------------------------------
# Materialize + facade conveniences + legacy shims
# ---------------------------------------------------------------------------


def test_materialize_reuses_fetch(setup):
    store, t0, t1 = setup
    q = store.subgraphs(t0, t1).materialize()
    assert q.operand is not None
    # downstream executes touch no storage
    reads0 = store.store.stats.reads
    q.timeslice((t0 + t1) // 2).execute()
    q.evolution(lambda s, t: float(len(s)), n_samples=3).execute()
    assert store.store.stats.reads == reads0


def test_operand_query_aggregate(setup):
    store, t0, t1 = setup
    sots = store.subgraphs(t0, t1).materialize().operand
    pts = sots.change_points()[::5][:10]

    def f(present, attrs, son, i, t):
        return float(present)

    ts_vals = TemporalQuery.over(sots).node_compute(
        f, style="temporal", points=pts).execute()
    agg = TemporalQuery.over(sots).node_compute(
        f, style="temporal", points=pts).aggregate("max").execute()
    np.testing.assert_allclose(agg, np.asarray(ts_vals[1]).max(axis=1))


def test_legacy_build_sots_matches_query(setup):
    store, t0, t1 = setup
    legacy = build_sots(store.tgi, t0, t1)
    new = store.subgraphs(t0, t1).run().operand
    assert (legacy.node_ids == new.node_ids).all()
    assert (legacy.ev_t == new.ev_t).all()
    assert (legacy.adj_nbr == new.adj_nbr).all()
