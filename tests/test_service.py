"""Service plane: wire codec (fuzzed round-trips, typed rejection of
truncated/oversized/garbage frames, version handshake), storage cells
over sockets (projection pushed to the server, corrupt-replica
failover across the process boundary), routed clients (parity with the
local store under TGI, hedged multiget, node_status), and change-feed
catch-up (kill -> write -> restart converges byte-identically)."""
import hashlib
import socket
import struct
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core.tgi import TGIConfig
from repro.data.temporal_graph_gen import generate, naive_state_at
from repro.service import ClusterSpec, LocalCluster, StorageCell
from repro.service import wire
from repro.service.client import RemoteDeltaStore
from repro.storage import serialize
from repro.storage.kvstore import (DeltaKey, DeltaStore, KeyMissing,
                                   split_vseq)
from repro.taf.query import HistoricalGraphStore


# ---------------------------------------------------------------------------
# wire codec (pure bytes — no sockets)
# ---------------------------------------------------------------------------


def test_frame_roundtrip_fuzz():
    rng = np.random.RandomState(0)
    for _ in range(200):
        body = rng.bytes(int(rng.randint(0, 4096)))
        mtype = int(rng.randint(1, 12))
        req_id = int(rng.randint(0, 2**32))
        buf = wire.encode_frame(mtype, req_id, body)
        frame, used = wire.decode_frame(buf + b"trailing junk")
        assert used == len(buf)
        assert frame == wire.Frame(wire.PROTO_VERSION, mtype, req_id, body)


def test_truncated_frames_rejected():
    buf = wire.encode_frame(wire.MSG_GET, 7, b"x" * 100)
    for cut in (0, 1, wire.HEADER.size - 1, wire.HEADER.size,
                wire.HEADER.size + 50, len(buf) - 1):
        with pytest.raises(wire.FrameError):
            wire.decode_frame(buf[:cut])


def test_oversized_frame_rejected():
    # a hostile header declaring a huge body must be rejected from the
    # 16 header bytes alone — before any allocation
    head = wire.HEADER.pack(wire.FRAME_MAGIC, wire.PROTO_VERSION,
                            wire.MSG_GET, 1, wire.MAX_FRAME + 1, 0)
    with pytest.raises(wire.FrameTooLarge):
        wire.decode_frame(head)
    with pytest.raises(wire.FrameTooLarge):
        wire.encode_frame(wire.MSG_PUT, 1, b"\0" * (wire.MAX_FRAME + 1))


def test_garbage_and_corrupt_frames_rejected():
    rng = np.random.RandomState(1)
    for _ in range(50):
        junk = rng.bytes(int(rng.randint(16, 256)))
        if junk[:2] == wire.FRAME_MAGIC:
            continue
        with pytest.raises((wire.FrameError, wire.FrameTooLarge)):
            wire.decode_frame(junk)
    good = wire.encode_frame(wire.MSG_OK, 3, b"payload bytes")
    flipped = bytearray(good)
    flipped[-1] ^= 0xFF  # body bit-flip -> crc mismatch, typed
    with pytest.raises(wire.FrameCorrupt):
        wire.decode_frame(bytes(flipped))


def test_body_codecs_roundtrip():
    key = DeltaKey(12, 3, "S:2:11", 4)
    k2, off = wire.unpack_key(wire.pack_key(key), 0)
    assert k2 == key and off == len(wire.pack_key(key))
    for fields in (None, [], ["a"], ["present", "attrs", "edge_key"]):
        out, _ = wire.unpack_fields(wire.pack_fields(fields), 0)
        assert out == fields
    recs = [wire.FeedRecord(5, wire.OP_PUT, key, 100, b"\x01\x02"),
            wire.FeedRecord(6, wire.OP_DELETE, key, 0, b"")]
    assert wire.unpack_records(wire.pack_records(recs)) == recs


def test_truncated_body_codecs_raise_not_truncate():
    """Every strict prefix of a packed string/blob/feed record must
    raise a typed error — silent short-slice truncation is how a torn
    feed tail used to masquerade as a valid record."""
    s = wire.pack_str("hello world")
    for cut in range(len(s)):
        with pytest.raises(wire.FrameError):
            wire.unpack_str(s[:cut], 0)
    b = wire.pack_blob(b"payload bytes")
    for cut in range(len(b)):
        with pytest.raises(wire.FrameError):
            wire.unpack_blob(b[:cut], 0)
    rec = wire.FeedRecord(9, wire.OP_PUT, DeltaKey(1, 2, "E:0", 3),
                          64, b"block bytes").pack()
    for cut in range(len(rec)):
        with pytest.raises((wire.WireError, struct.error)):
            wire.FeedRecord.unpack(rec[:cut], 0)


# ---------------------------------------------------------------------------
# handshake + single cell over a real socket
# ---------------------------------------------------------------------------


@pytest.fixture()
def one_cell(tmp_path):
    cell = StorageCell(node_id=0, n_cells=1, r=1, backend="file",
                       root=str(tmp_path / "cell0"))
    cell.start()
    yield cell
    cell.stop()


@pytest.mark.timeout(30)
def test_protocol_version_mismatch_handshake(one_cell):
    with socket.create_connection(("127.0.0.1", one_cell.port),
                                  timeout=5) as s:
        s.settimeout(5)
        wire.send_frame(s, wire.MSG_HELLO, 1,
                        version=wire.PROTO_VERSION + 1)
        reply = wire.recv_frame(s)
    assert reply.msg_type == wire.MSG_ERR
    code, msg = wire.unpack_err(reply.body)
    assert code == wire.ERR_VERSION
    assert f"v{wire.PROTO_VERSION}" in msg
    # the client maps that rejection to a typed ProtocolMismatch
    store = RemoteDeltaStore([("127.0.0.1", one_cell.port)], r=1)
    orig = wire.PROTO_VERSION
    try:
        wire.PROTO_VERSION = orig + 1
        with pytest.raises(wire.ProtocolMismatch):
            store._request(0, wire.MSG_PING, b"")
    finally:
        wire.PROTO_VERSION = orig
        store.close()


@pytest.mark.timeout(60)
def test_cell_roundtrip_and_projection_pushdown(one_cell):
    """Column projection survives the network hop: the *server's*
    physical file I/O for a projected GET is a fraction of the full
    blob (the acceptance criterion's server-measured bytes_io)."""
    store = RemoteDeltaStore([("127.0.0.1", one_cell.port)], r=1,
                             pool_bytes=0)
    key = DeltaKey(0, 0, "S:0:0", 0)
    arrays = {"big": np.random.RandomState(0).randn(200_000).astype(np.float32),
              "small": np.arange(64, dtype=np.int64)}
    store.put(key, arrays)
    one_cell.store.stats.reset()
    got = store.get(key, fields=["small"])
    assert set(got) == {"small"}
    np.testing.assert_array_equal(got["small"], arrays["small"])
    proj_io = one_cell.store.stats.bytes_io
    one_cell.store.stats.reset()
    full = store.get(key)
    np.testing.assert_array_equal(full["big"], arrays["big"])
    full_io = one_cell.store.stats.bytes_io
    assert 0 < proj_io < full_io / 10, (proj_io, full_io)
    # server-side status report agrees with the client-held accounting:
    # one write, stamped (epoch, seq=1) under the client's writer lease
    status = store.cell_status(0)
    epoch, seq = split_vseq(status["last_seq"])
    assert status["n_keys"] == 1 and seq == 1 and epoch >= 1
    store.close()


@pytest.mark.timeout(60)
def test_put_delete_missing_over_wire(one_cell):
    store = RemoteDeltaStore([("127.0.0.1", one_cell.port)], r=1)
    key = DeltaKey(1, 0, "E:0", 0)
    with pytest.raises(KeyMissing):
        store.get(key)
    store.put(key, {"x": np.arange(10)})
    assert store.get(key)["x"].sum() == 45
    assert store.delete(key) is True
    store.clear_pool()
    with pytest.raises(KeyMissing):
        store.get(key)
    out = store.multiget([key], missing_ok=True)
    assert out == {}
    store.close()


@pytest.mark.timeout(60)
def test_feed_since_and_seq_dedupe(one_cell):
    key = DeltaKey(0, 0, "E:0", 0)
    blob, raw = DeltaStore(m=1, r=1, backend="mem").encode_payload(
        key, {"x": np.arange(32)})
    rec = wire.FeedRecord(1, wire.OP_PUT, key, raw, blob)
    assert one_cell.apply(rec) == (True, True)
    assert one_cell.apply(rec) == (False, False)  # duplicate seq: dropped
    assert [r.seq for r in one_cell.feed_since(0)] == [1]
    assert one_cell.feed_since(1) == []
    assert one_cell.apply(
        wire.FeedRecord(2, wire.OP_DELETE, key, 0, b"")) == (True, True)
    assert [r.op for r in one_cell.feed_since(0)] == [wire.OP_PUT,
                                                      wire.OP_DELETE]


# ---------------------------------------------------------------------------
# clusters: parity, failover, hedging, catch-up
# ---------------------------------------------------------------------------


def _fill(store, n_ts=4, n_sid=3):
    rng = np.random.RandomState(3)
    keys = [DeltaKey(t, s, "E:0", p) for t in range(n_ts)
            for s in range(n_sid) for p in range(2)]
    for k in keys:
        store.put(k, {"t": np.arange(150, dtype=np.int64) * (k.tsid + 1),
                      "v": rng.randn(150).astype(np.float32)})
    return keys


@pytest.mark.timeout(120)
def test_cluster_parity_with_local_store(tmp_path):
    """The same TGI build + snapshot query over a 3x r=2 wire cluster
    and over the in-process store produce identical graph state — the
    drop-in property the client is built for."""
    events = generate(2500, seed=11)
    cfg = TGIConfig(n_shards=3, parts_per_shard=2, events_per_span=900,
                    eventlist_size=128, checkpoints_per_span=4)
    spec = ClusterSpec(n_cells=3, r=2, backend="file",
                       root=str(tmp_path / "cluster"))
    with LocalCluster(spec, mode="thread") as cl:
        remote = cl.client(timeout=5.0)
        hs = HistoricalGraphStore.build(events, cfg, store=remote)
        t0, t1 = events.time_range()
        for frac in (0.25, 0.8):
            t = int(t0 + frac * (t1 - t0))
            got = hs.tgi.get_snapshot(t, c=4)
            want = naive_state_at(events, t, cfg.n_attrs)
            n = max(len(got.present), len(want.present))
            got.grow(n)
            want.grow(n)
            assert (got.present == want.present).all()
            assert (got.edge_key == want.edge_key).all()
            assert (got.edge_val == want.edge_val).all()
        # the lazy query surface (PlanExecutor fetch) runs unchanged too
        dens = hs.density_evolution(t0, t1, n_samples=4)
        assert len(dens) >= 1
        remote.close()


@pytest.mark.timeout(120)
def test_kill_replica_failover_and_hedging(tmp_path):
    """One dead cell must cost zero failed reads: every key stays
    servable through its surviving replica, the client counts the
    failovers, and once the cell is a known suspect whole multiget
    groups are hedged straight to the fallback."""
    spec = ClusterSpec(n_cells=3, r=2, backend="file",
                       root=str(tmp_path / "cluster"))
    with LocalCluster(spec, mode="subprocess") as cl:
        store = cl.client(timeout=2.0, retries=1, backoff=0.02,
                          suspect_ttl=30.0)
        keys = _fill(store)
        cl.kill(0)
        store.clear_pool()
        out = store.multiget(keys, c=4)  # discovery pass: timeouts -> failover
        assert len(out) == len(keys)
        assert store.stats.failovers > 0
        store.clear_pool()
        out = store.multiget(keys, c=4)  # suspect pass: hedged batches
        assert len(out) == len(keys)
        assert store.stats.hedged_reads > 0
        # single gets on a suspect node fail over without a timeout wait
        store.clear_pool()
        for k in keys:
            assert "t" in store.get(k)
        store.close()


@pytest.mark.timeout(120)
def test_restart_catch_up_converges_byte_identical(tmp_path):
    """Kill a cell, keep writing (it misses records), restart it: after
    ``feed_since`` catch-up its chunk, extent, AND feed files are byte-
    for-byte what they would have been had it never died."""

    def run(root, kill):
        spec = ClusterSpec(n_cells=3, r=2, backend="file", root=str(root))
        with LocalCluster(spec, mode="subprocess") as cl:
            store = cl.client(timeout=2.0, retries=1, backoff=0.02,
                              suspect_ttl=0.2)
            rng = np.random.RandomState(5)
            keys = [DeltaKey(t, s, "E:0", p) for t in range(4)
                    for s in range(3) for p in range(2)]
            half = len(keys) // 2
            for k in keys[:half]:
                store.put(k, {"t": np.arange(100, dtype=np.int64),
                              "v": rng.randn(100).astype(np.float32)})
            if kill:
                cl.kill(0)
            for k in keys[half:]:  # cell 0 misses its share of these
                store.put(k, {"t": np.arange(100, dtype=np.int64),
                              "v": rng.randn(100).astype(np.float32)})
            store.delete(keys[1])
            if kill:
                cl.restart(0)
            # quiesce, then verify every live key is readable cluster-wide
            store.clear_pool()
            store._suspects.clear()
            for k in keys:
                if k == keys[1]:
                    continue
                assert "t" in store.get(k)
            store.close()
        return {
            str(p.relative_to(root)): hashlib.sha256(p.read_bytes()).hexdigest()
            for p in sorted(Path(root, "cell0").rglob("*")) if p.is_file()
        }

    baseline = run(tmp_path / "a", kill=False)
    recovered = run(tmp_path / "b", kill=True)
    assert baseline == recovered
    assert any(f.endswith(".tgi") for f in baseline)  # chunks exist
    assert any(f.endswith(".tgx") for f in baseline)  # extents exist
    assert "cell0/feed.log" in baseline


@pytest.mark.timeout(90)
def test_corrupt_replica_fails_over_across_the_wire(tmp_path):
    """PR 5's corrupt-replica failover, across the process boundary:
    flip payload bytes in one cell's chunk file on disk — the client's
    per-column crc check rejects that replica's reply and the read is
    served by the other copy."""
    spec = ClusterSpec(n_cells=3, r=2, backend="file",
                       root=str(tmp_path / "cluster"))
    with LocalCluster(spec, mode="thread") as cl:
        store = cl.client(timeout=5.0)
        key = DeltaKey(0, 0, "E:0", 0)
        store.put(key, {"x": np.arange(4096, dtype=np.int64)})
        primary = store.replicas(key)[0]
        chunk = Path(spec.cell_root(primary), "node0", "ts0_s0.tgi")
        data = bytearray(chunk.read_bytes())
        data[-64:] = b"\xff" * 64  # trash payload tail bytes
        chunk.write_bytes(bytes(data))
        cl._cells[primary].store._ext_cache.clear()  # drop cached extents
        store.clear_pool()
        got = store.get(key)
        np.testing.assert_array_equal(got["x"], np.arange(4096))
        assert store.stats.failovers > 0
        store.close()


@pytest.mark.timeout(60)
def test_node_status_uniform_local_and_remote(tmp_path):
    """Chaos tooling asserts cluster health through ONE shape, whatever
    the backend: same keys, same per-node fields, live keys counted on
    every replica."""
    local = DeltaStore(m=3, r=2, backend="mem")
    _fill(local, n_ts=2, n_sid=2)
    local.fail_node(1)
    ls = local.node_status()
    spec = ClusterSpec(n_cells=3, r=2, backend="file",
                       root=str(tmp_path / "cluster"))
    with LocalCluster(spec, mode="thread") as cl:
        remote = cl.client(timeout=5.0)
        _fill(remote, n_ts=2, n_sid=2)
        cl.kill(1)
        rs = remote.node_status()
        remote.close()
    assert set(ls) == set(rs)
    assert [set(n) for n in ls["nodes"]] == [set(n) for n in rs["nodes"]]
    assert ls["n_down"] == rs["n_down"] == 1
    assert [n["up"] for n in ls["nodes"]] == [n["up"] for n in rs["nodes"]]
    # replicated keys are visible on r nodes in both worlds
    assert sum(n["live_keys"] for n in ls["nodes"]) == \
        sum(n["live_keys"] for n in rs["nodes"])


def test_hedged_multiget_local_store():
    """The hedging satellite on the in-process store: keys whose
    primary node is down are redirected as a batch and counted."""
    store = DeltaStore(m=4, r=2, backend="mem", pool_bytes=0)
    keys = _fill(store)
    down = store.replicas(keys[0])[0]
    store.fail_node(down)
    out = store.multiget(keys, c=4)
    assert len(out) == len(keys)
    assert store.stats.hedged_reads > 0
    assert store.stats.failovers > 0
    # node_status reflects the injected failure
    ns = store.node_status()
    assert ns["n_down"] == 1 and not ns["nodes"][down]["up"]


@pytest.mark.timeout(60)
def test_unreachable_cell_then_ttl_reprobe(tmp_path):
    """A suspect cell is skipped for suspect_ttl seconds (no repeated
    timeout tax), then re-probed and readmitted once it is back."""
    spec = ClusterSpec(n_cells=2, r=2, backend="file",
                       root=str(tmp_path / "cluster"))
    with LocalCluster(spec, mode="subprocess") as cl:
        store = cl.client(timeout=1.0, retries=0, backoff=0.01,
                          suspect_ttl=0.5)
        key = DeltaKey(0, 0, "E:0", 0)
        store.put(key, {"x": np.arange(10)})
        victim = store.replicas(key)[0]
        cl.kill(victim)
        store.clear_pool()
        assert "x" in store.get(key)  # discovery: timeout then failover
        assert not store._node_ok(victim)  # suspect now
        cl.restart(victim)
        time.sleep(0.6)  # TTL expiry readmits it
        assert store._node_ok(victim)
        store.clear_pool()
        assert "x" in store.get(key)
        store.close()


@pytest.mark.timeout(60)
def test_malformed_request_gets_typed_error_not_hang(one_cell):
    """A structurally broken request body must come back as a
    BAD_REQUEST error frame — the connection survives and the cell
    never wedges."""
    with socket.create_connection(("127.0.0.1", one_cell.port),
                                  timeout=5) as s:
        s.settimeout(5)
        wire.send_frame(s, wire.MSG_GET, 9, b"\x01\x02\x03")  # torn key
        reply = wire.recv_frame(s)
        assert reply.msg_type == wire.MSG_ERR
        code, _ = wire.unpack_err(reply.body)
        assert code in (wire.ERR_BAD_REQUEST, wire.ERR_INTERNAL)
        # same connection still serves good requests afterwards
        wire.send_frame(s, wire.MSG_PING, 10)
        reply = wire.recv_frame(s)
        assert reply.msg_type == wire.MSG_OK
        node, _seq = struct.unpack("<BQ", reply.body)
        assert node == 0


# ---------------------------------------------------------------------------
# gap repair: redelivery queues, full-feed catch-up, torn feed tails
# ---------------------------------------------------------------------------


def _encode(key, arrays):
    return DeltaStore(m=1, r=1, backend="mem").encode_payload(key, arrays)


@pytest.mark.timeout(60)
def test_interior_gap_repaired_by_redelivery(tmp_path):
    """A replica that missed an acknowledged write while transiently
    down must NOT serve the stale previous version once it is back: the
    client drains its redelivery queue for that node before routing a
    read to it (the record reaches the node even though no restart
    catch-up ever ran)."""
    from repro.storage.kvstore import replica_nodes

    cells = {}

    def spawn(node, port=0):
        c = StorageCell(node_id=node, n_cells=2, r=2, backend="file",
                        root=str(tmp_path / f"cell{node}"), port=port)
        c.start()  # deliberately NO peers: boot catch-up stays out of it
        cells[node] = c
        return c

    a, b = spawn(0), spawn(1)
    # key whose PRIMARY replica is cell 1 — reads route there first
    key = DeltaKey(1, 0, "E:0", 0)
    assert replica_nodes(key.tsid, key.sid, 2, 2)[0] == 1
    store = RemoteDeltaStore([("127.0.0.1", a.port), ("127.0.0.1", b.port)],
                             r=2, timeout=1.0, retries=0, backoff=0.01,
                             suspect_ttl=60.0, pool_bytes=0)
    store.put(key, {"x": np.zeros(64, dtype=np.int64)})     # seq 1: both
    b.stop()
    v2 = np.arange(64, dtype=np.int64)
    store.put(key, {"x": v2})  # seq 2: acked by cell 0, queued for cell 1
    assert store._pending[1], "missed replica write must be queued"
    spawn(1)  # cell 1 returns (fresh port), still missing seq 2
    store.addrs[1] = ("127.0.0.1", cells[1].port)
    store._suspects.clear()
    got = store.get(key)   # routed to cell 1 -> drain queue first
    np.testing.assert_array_equal(got["x"], v2)
    assert store.stats.redelivered >= 1
    assert not store._pending[1]
    assert split_vseq(cells[1].last_seq) == (1, 2)
    store.close()
    for c in cells.values():
        c.stop()


@pytest.mark.timeout(60)
def test_catch_up_repairs_interior_gaps(tmp_path):
    """Restart catch-up pulls the FULL peer feed and dedupes by the
    applied-seq set, so a seq hole *below* the cell's last_seq (a write
    missed while live) is repaired — and a repair arriving after a
    newer write of the same key is recorded without regressing it."""
    key1 = DeltaKey(0, 0, "E:0", 0)
    key2 = DeltaKey(2, 0, "E:0", 0)
    b1, r1 = _encode(key1, {"x": np.arange(16, dtype=np.int64)})
    b2, r2 = _encode(key2, {"x": np.arange(32, dtype=np.int64)})
    b3, r3 = _encode(key1, {"x": np.arange(16, dtype=np.int64) * 7})
    recs = [wire.FeedRecord(1, wire.OP_PUT, key1, r1, b1),
            wire.FeedRecord(2, wire.OP_PUT, key2, r2, b2),
            wire.FeedRecord(3, wire.OP_PUT, key1, r3, b3)]
    peer = StorageCell(node_id=0, n_cells=2, r=2, backend="file",
                       root=str(tmp_path / "peer"))
    for rec in recs:
        peer.apply(rec)
    peer.start()
    # the gapped cell saw only seq 3: seqs 1 AND 2 are interior holes
    cell = StorageCell(node_id=1, n_cells=2, r=2, backend="file",
                       root=str(tmp_path / "gapped"))
    cell.apply(recs[2])
    assert cell.last_seq == 3
    applied = cell.catch_up([("127.0.0.1", peer.port)])
    assert applied == 2  # both holes backfilled
    assert sorted(cell._applied) == [1, 2, 3]
    # the missed key materialized...
    arrays, _, _ = serialize.loads_sized(cell.store.get_encoded(key2, None))
    np.testing.assert_array_equal(arrays["x"], np.arange(32))
    # ...and the late seq-1 repair did NOT regress key1 past seq 3
    arrays, _, _ = serialize.loads_sized(cell.store.get_encoded(key1, None))
    np.testing.assert_array_equal(arrays["x"], np.arange(16) * 7)
    # a second catch-up is a no-op: everything dedupes
    assert cell.catch_up([("127.0.0.1", peer.port)]) == 0
    peer.stop()
    cell.stop()


@pytest.mark.timeout(60)
def test_torn_feed_tail_truncated_then_refetched(tmp_path):
    """SIGKILL can tear the last feed.log record.  Boot must not die
    (restart/catch-up would be impossible) and must not load a silently
    corrupt record (it would be served to catching-up peers): the torn
    tail is truncated and the lost suffix comes back via catch-up."""
    root = tmp_path / "cell"
    key1 = DeltaKey(0, 0, "E:0", 0)
    key2 = DeltaKey(1, 0, "E:0", 0)
    b1, r1 = _encode(key1, {"x": np.arange(8, dtype=np.int64)})
    b2, r2 = _encode(key2, {"x": np.arange(24, dtype=np.int64)})
    rec1 = wire.FeedRecord(1, wire.OP_PUT, key1, r1, b1)
    rec2 = wire.FeedRecord(2, wire.OP_PUT, key2, r2, b2)
    cell = StorageCell(node_id=0, n_cells=1, r=1, backend="file",
                       root=str(root))
    cell.apply(rec1)
    cell.stop()
    feed = root / "feed.log"
    whole = feed.read_bytes()
    for torn_tail in (rec2.pack()[:11], b"\xff" * 17):
        feed.write_bytes(whole + torn_tail)
        reborn = StorageCell(node_id=0, n_cells=1, r=1, backend="file",
                             root=str(root))
        assert reborn.last_seq == 1 and len(reborn._feed) == 1
        assert feed.read_bytes() == whole  # torn tail truncated away
        reborn.stop()
    # the lost record is refetched from a peer that has it
    peer = StorageCell(node_id=0, n_cells=1, r=1, backend="file",
                       root=str(tmp_path / "peer"))
    peer.apply(rec1)
    peer.apply(rec2)
    peer.start()
    reborn = StorageCell(node_id=0, n_cells=1, r=1, backend="file",
                         root=str(root))
    assert reborn.catch_up([("127.0.0.1", peer.port)]) == 1
    assert reborn.last_seq == 2
    arrays, _, _ = serialize.loads_sized(reborn.store.get_encoded(key2, None))
    np.testing.assert_array_equal(arrays["x"], np.arange(24))
    peer.stop()
    reborn.stop()


@pytest.mark.timeout(60)
def test_delete_with_all_replicas_down_raises(one_cell):
    """A delete no replica acked must raise StorageNodeDown (like put)
    with the local accounting untouched — not silently 'succeed' while
    the key stays live on the cluster."""
    from repro.storage.kvstore import StorageNodeDown

    store = RemoteDeltaStore([("127.0.0.1", one_cell.port)], r=1,
                             timeout=1.0, retries=0, backoff=0.01)
    key = DeltaKey(0, 0, "E:0", 0)
    store.put(key, {"x": np.arange(10)})
    one_cell.stop()
    with pytest.raises(StorageNodeDown):
        store.delete(key)
    assert key in store.key_sizes  # accounting untouched by the failure
    assert store.stats.n_deletes == 0
    store.close()


@pytest.mark.timeout(60)
def test_quorum_loss_degrades_writes_but_reads_survive(tmp_path):
    """Attach is lazy (a lease is acquired at the first write), so a
    client can always come up against a degraded cluster — but without
    a cell quorum the write plane must fail with the typed
    WriteUnavailable (fast once degraded, not one timeout per call)
    while reads keep failing over to the surviving replica."""
    from repro.storage.kvstore import WriteUnavailable

    spec = ClusterSpec(n_cells=2, r=2, backend="file",
                       root=str(tmp_path / "cluster"))
    with LocalCluster(spec, mode="thread") as cl:
        w = cl.client(timeout=1.0, retries=0, backoff=0.01)
        key = DeltaKey(0, 0, "E:0", 0)
        w.put(key, {"x": np.arange(12)})
        w.close()
        cl.kill(0)
        # quorum is 2/2 — with a cell dead, no lease can be granted
        ro = cl.client(timeout=0.5, retries=0, backoff=0.01)
        assert "x" in ro.get(key)  # served by the surviving replica
        with pytest.raises(WriteUnavailable):
            ro.put(key, {"x": np.arange(3)})
        assert ro.lease_status()["degraded"]
        t0 = time.monotonic()
        with pytest.raises(WriteUnavailable):  # degraded -> fail fast
            ro.put(key, {"x": np.arange(3)})
        assert time.monotonic() - t0 < 0.25
        assert "x" in ro.get(key)  # reads still fine after the refusals
        ro.close()


@pytest.mark.timeout(60)
def test_remote_storage_report_through_tgi(tmp_path):
    """TGI.storage_report carries the node_status block for remote
    stores too — the integration the chaos tooling reads."""
    events = generate(800, seed=2)
    cfg = TGIConfig(n_shards=2, parts_per_shard=1, events_per_span=500,
                    eventlist_size=64, checkpoints_per_span=2)
    spec = ClusterSpec(n_cells=2, r=2, backend="file",
                       root=str(tmp_path / "cluster"))
    with LocalCluster(spec, mode="thread") as cl:
        remote = cl.client(timeout=5.0)
        hs = HistoricalGraphStore.build(events, cfg, store=remote)
        rep = hs.tgi.storage_report()
        assert rep["nodes"]["m"] == 2 and rep["nodes"]["n_down"] == 0
        assert rep["nodes"]["backend"] == "remote"
        assert sum(n["live_keys"] for n in rep["nodes"]["nodes"]) > 0
        cs = hs.cache_stats()
        assert "failovers" in cs and "hedged_reads" in cs
        remote.close()


@pytest.mark.timeout(240)
def test_sigkill_during_compaction_failover_and_catch_up(tmp_path):
    """The MVCC maintenance chaos case: a storage cell SIGKILLs itself
    mid-compaction (armed via ``REPRO_FAULTPOINTS=cell.apply=N:kill``
    in its subprocess environment) while the client's maintenance
    thread is in the middle of the shadow-build write storm.  The pass
    must still converge through the surviving replicas, reads stay
    bit-identical, and a clean restart catch-up repairs the dead
    cell's copies so they can serve alone."""
    events = generate(2400, seed=13)
    cfg = TGIConfig(n_shards=2, parts_per_shard=2, events_per_span=300,
                    eventlist_size=64, checkpoints_per_span=2)
    spec = ClusterSpec(n_cells=3, r=2, backend="file",
                       root=str(tmp_path / "cluster"))
    with LocalCluster(spec, mode="subprocess") as cl:
        store = cl.client(timeout=2.0, retries=1, backoff=0.02,
                          suspect_ttl=30.0)
        init = events.take(slice(0, 1200))
        rest = events.take(slice(1200, 2400))
        hs = HistoricalGraphStore.build(init, cfg, store=store)
        for lo in range(0, len(rest), 100):
            hs.tgi.update(rest.take(slice(lo, lo + 100)))
        hs.tgi.flush()
        # re-arm cell 1 with the kill switch: it is fully caught up, so
        # boot catch-up applies nothing — the 5th record it applies will
        # be a compaction write, and acting on it means SIGKILL
        cl.kill(1)
        cl.spec.cell_env = {1: {"REPRO_FAULTPOINTS": "cell.apply=5:kill"}}
        cl.restart(1)
        store.clear_pool()
        store._suspects.clear()
        stats = hs.compact(min_run=2)
        assert stats.runs_merged >= 1
        # the cell really died by its own hand, mid write storm
        proc = cl._procs[1]
        assert proc is not None
        for _ in range(100):
            if proc.poll() is not None:
                break
            time.sleep(0.1)
        assert proc.poll() == -9
        # the pass converged anyway: superseded chunks reclaimed (the
        # deferred-GC deletes were acked by surviving replicas)...
        assert store.gc_pending() == 0
        # ...and every read is bit-identical through the failover path
        t0, t1 = events.time_range()
        store.clear_pool()

        def probe(msg):
            for frac in (0.3, 0.9):
                t = int(t0 + frac * (t1 - t0))
                got = hs.tgi.get_snapshot(t)
                want = naive_state_at(events, t, cfg.n_attrs)
                n = max(len(got.present), len(want.present))
                got.grow(n)
                want.grow(n)
                assert (got.present == want.present).all(), msg
                assert (got.edge_key == want.edge_key).all(), msg
                assert (got.edge_val == want.edge_val).all(), msg

        probe("reads during dead-cell window")
        # clean restart (no fault env): feed catch-up repairs everything
        # cell 1 missed while dead
        cl.spec.cell_env = None
        cl.restart(1)
        # force the repaired copies to serve alone: kill the OTHER
        # replica, so every {1,2}-chained key must come from cell 1
        cl.kill(2)
        store.clear_pool()
        store._suspects.clear()
        probe("reads served by the repaired cell")
        store.close()


@pytest.mark.timeout(90)
def test_maint_vacuum_over_wire(tmp_path):
    """MSG_MAINT: a cell acks immediately, vacuums on a background
    thread, keeps serving mid-pass, and surfaces the rewrite counters
    in its status block."""
    spec = ClusterSpec(n_cells=2, r=2, backend="file",
                       root=str(tmp_path / "cluster"))
    with LocalCluster(spec, mode="thread") as cl:
        store = cl.client(timeout=5.0)
        keys = _fill(store)
        for k in keys[::3]:  # tombstones = vacuumable garbage
            store.delete(k)
        assert store.maintain(0) is True
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            maint = store.cell_status(0)["maint"]
            # serving while (possibly) vacuuming: reads must not block
            store.clear_pool()
            assert "t" in store.get(keys[1])
            if not maint["running"] and maint["last_vacuum"] is not None:
                break
            time.sleep(0.05)
        lv = store.cell_status(0)["maint"]["last_vacuum"]
        assert lv is not None and lv["chunks_scanned"] >= 1
        assert lv["bytes_after"] <= lv["bytes_before"]
        # everything live is still readable after the rewrite
        store.clear_pool()
        for k in keys:
            if k in keys[::3]:
                continue
            assert "t" in store.get(k)
        store.close()
