"""Read-path overhaul tests: decoded-block buffer-pool semantics
(hit/miss/eviction accounting, invalidation on every write path,
bit-identical results pool on vs off), the range-seek file backend
(extent sidecars, projected byte savings, reopen, tombstones), per-
column checksums, the snapshot-LRU/pool accounting parity, chunked
event-log storage, and cost-based plan selection."""
import numpy as np
import pytest

from repro.core.events import ChunkedEventLog, EventLog
from repro.core.tgi import TGI, TGIConfig
from repro.data.temporal_graph_gen import generate, naive_state_at
from repro.storage import serialize as S
from repro.storage.kvstore import (
    BlockCorruption,
    BlockPool,
    DeltaKey,
    DeltaStore,
    KeyMissing,
)

CFG = dict(n_shards=2, parts_per_shard=2, events_per_span=800,
           eventlist_size=128, checkpoints_per_span=2)


def _build(n=2000, seed=13, store=None, **kw):
    events = generate(n, seed=seed)
    cfg = TGIConfig(**{**CFG, **kw})
    store = store or DeltaStore(m=2, r=1, backend="mem")
    return events, cfg, store, TGI.build(events, cfg, store)


def _states_equal(a, b):
    n = max(len(a.present), len(b.present))
    a.grow(n)
    b.grow(n)
    assert (a.present == b.present).all()
    on = a.present == 1
    assert (a.attrs[on] == b.attrs[on]).all()
    assert (a.edge_key == b.edge_key).all()
    assert (a.edge_val == b.edge_val).all()


# ---------------------------------------------------------------------------
# Buffer-pool semantics
# ---------------------------------------------------------------------------


def _arrays(rng, n=1500):
    return {"t": np.sort(rng.randint(0, 10**6, n)).astype(np.int64),
            "x": rng.randint(-1, 4, (n // 4, 4)).astype(np.int32)}


def test_pool_hit_miss_accounting():
    rng = np.random.RandomState(0)
    store = DeltaStore(m=2, r=1, backend="mem")
    key = DeltaKey(0, 0, "S:0:0", 0)
    arrays = _arrays(rng)
    store.put(key, arrays)
    store.get(key)  # cold: every column is a physical decode
    assert store.stats.pool_hits == 0
    assert store.stats.pool_misses == len(arrays)
    dec0 = store.stats.bytes_decompressed
    out = store.get(key)  # warm: fully pool-served
    assert store.stats.pool_hits == len(arrays)
    assert store.stats.bytes_decompressed == dec0  # no new physical decode
    assert store.stats.bytes_pool_served == sum(v.nbytes for v in arrays.values())
    for k, v in arrays.items():
        assert np.array_equal(out[k], v)
    # partial hit: a projected first read pools only one column
    key2 = DeltaKey(0, 1, "S:0:1", 0)
    store.put(key2, arrays)
    store.get(key2, fields=["t"])
    sizes = {}
    store.get(key2, sizes=sizes)  # "t" pooled, "x" physical
    s = sizes[key2]
    assert s.pool_cols == 1 and s.pool == arrays["t"].nbytes
    assert s.raw == arrays["x"].nbytes


def test_pool_eviction_is_lru_and_byte_budgeted():
    rng = np.random.RandomState(1)
    arrs = {f"k{i}": {"a": rng.randint(0, 100, 600).astype(np.int64)}
            for i in range(4)}
    one = 600 * 8
    pool = BlockPool(budget_bytes=int(one * 2.5))  # fits two entries
    keys = {n: DeltaKey(0, 0, n, 0) for n in arrs}
    pool.put(keys["k0"], "a", arrs["k0"]["a"])
    pool.put(keys["k1"], "a", arrs["k1"]["a"])
    assert pool.bytes_cached == 2 * one
    assert pool.get(keys["k0"], "a") is not None  # touch k0: k1 becomes LRU
    pool.put(keys["k2"], "a", arrs["k2"]["a"])  # evicts k1, not k0
    assert pool.evictions == 1
    assert pool.peek(keys["k0"], "a") and pool.peek(keys["k2"], "a")
    assert not pool.peek(keys["k1"], "a")
    assert pool.bytes_cached <= pool.budget
    # an entry bigger than the whole budget is not cacheable
    big = np.zeros(10**6, np.int64)
    pool.put(keys["k3"], "a", big)
    assert not pool.peek(keys["k3"], "a")


def test_pool_invalidation_on_put_and_delete():
    rng = np.random.RandomState(2)
    store = DeltaStore(m=2, r=1, backend="mem")
    key = DeltaKey(0, 0, "S:0:0", 0)
    a1 = {"v": rng.randint(0, 100, 500).astype(np.int32)}
    a2 = {"v": rng.randint(100, 200, 500).astype(np.int32)}
    store.put(key, a1)
    store.get(key)
    store.put(key, a2)  # rewrite must invalidate pooled blocks
    assert np.array_equal(store.get(key)["v"], a2["v"])
    store.get(key)  # re-pool
    store.delete(key)  # GC must invalidate too — never serve deleted keys
    with pytest.raises(KeyMissing):
        store.get(key)


@pytest.mark.parametrize("backend", ["mem", "file"])
def test_bitidentical_pool_on_vs_off_randomized(tmp_path, backend):
    """Randomized event streams through build/update/append/compact:
    snapshots and node histories must be bit-identical with the pool on
    vs off, and the raw-byte accounting must agree:
    decompressed(on) + pool(on) == decompressed(off)."""
    events = generate(3000, seed=29)
    cut1, cut2 = 1500, 2200
    tgis = {}
    for mode, pool_bytes in (("on", 32 << 20), ("off", 0)):
        kw = (dict(backend="file", root=str(tmp_path / mode))
              if backend == "file" else dict(backend="mem"))
        store = DeltaStore(m=2, r=1, pool_bytes=pool_bytes, **kw)
        tgi = TGI.build(events.take(slice(0, cut1)), TGIConfig(**CFG), store)
        tgi.update(events.take(slice(cut1, cut2)))
        tgi.append(events.take(slice(cut2, len(events))))
        tgi.flush()
        tgis[mode] = tgi
    t0, t1 = events.time_range()
    probe_ts = [int(t0 + f * (t1 - t0)) for f in (0.1, 0.45, 0.8, 0.99)]
    for t in probe_ts:
        a = tgis["on"].get_snapshot(t)
        cost_on = tgis["on"].last_cost
        b = tgis["off"].get_snapshot(t)
        cost_off = tgis["off"].last_cost
        _states_equal(a, b)
        _states_equal(a, naive_state_at(events, t, TGIConfig(**CFG).n_attrs))
        assert cost_off.n_bytes_pool == 0
        assert cost_on.n_bytes_raw_total == cost_off.n_bytes_raw_total
    # repeat reads (warm pool) stay bit-identical and keep the invariant
    for t in probe_ts:
        tgis["on"].invalidate_caches(drop_pool=False)
        tgis["off"].invalidate_caches()
        a = tgis["on"].get_snapshot(t)
        cost_on = tgis["on"].last_cost
        b = tgis["off"].get_snapshot(t)
        _states_equal(a, b)
        assert cost_on.n_bytes_pool > 0  # the warm read really used the pool
        assert (cost_on.n_bytes_raw_total
                == tgis["off"].last_cost.n_bytes_raw_total)
    # node histories too
    nid = int(a.node_ids()[0])
    ia, eva = tgis["on"].get_node_history(nid, probe_ts[0], probe_ts[-1])
    ib, evb = tgis["off"].get_node_history(nid, probe_ts[0], probe_ts[-1])
    assert (ia is None) == (ib is None)
    assert len(eva) == len(evb) and (eva.t == evb.t).all()
    # compaction GC invalidates per key; results stay correct after
    for mode in ("on", "off"):
        tgis[mode].compact()
    for t in probe_ts:
        _states_equal(tgis["on"].get_snapshot(t), tgis["off"].get_snapshot(t))


def test_snapshot_lru_pool_accounting_parity():
    """Satellite fix: a snapshot-LRU hit replays the *fill-time*
    physical-vs-pool split — pool-served bytes are never re-counted as
    decompression, and the replayed cost is field-identical."""
    events, cfg, store, tgi = _build(n=2500)
    sp = tgi.spans[1].span  # two times in ONE span: they share blocks
    ta = int(sp.t_start + 0.40 * (sp.t_end - sp.t_start))
    tb = int(sp.t_start + 0.45 * (sp.t_end - sp.t_start))
    tgi.get_snapshot(ta)
    cost_a = tgi.last_cost.copy()
    assert cost_a.n_bytes_pool == 0  # cold store: everything physical
    tgi.get_snapshot(tb)
    cost_b = tgi.last_cost.copy()
    assert cost_b.n_bytes_pool > 0  # warm blocks came from the pool
    assert cost_b.n_bytes_decompressed < cost_a.n_bytes_decompressed
    # LRU replay of tb: identical on every dimension, pool split included
    # (before the fix, the replay re-reported pool bytes as decompression)
    tgi.get_snapshot(tb)
    assert tgi.last_cost == cost_b


# ---------------------------------------------------------------------------
# Range-seek file backend
# ---------------------------------------------------------------------------


def test_range_seek_matches_wholefile_and_reads_fewer_bytes(tmp_path):
    events = generate(2000, seed=7)
    cfg = TGIConfig(**CFG)
    tgis = {}
    for mode, seek in (("whole", False), ("seek", True)):
        store = DeltaStore(m=2, r=1, backend="file",
                           root=str(tmp_path / mode), seek=seek, pool_bytes=0)
        tgis[mode] = TGI.build(events, cfg, store)
    t = int(np.mean(events.time_range()))
    a = tgis["whole"].get_snapshot(t)
    b = tgis["seek"].get_snapshot(t)
    _states_equal(a, b)
    # projected reads: range-seek touches a fraction of the file bytes
    ratios = {}
    for mode in tgis:
        st = tgis[mode].store.stats
        tgis[mode].invalidate_caches()
        st.reset()
        tgis[mode].get_snapshot(t, projection=())  # attrs tiles skipped
        ratios[mode] = st.bytes_io
    assert ratios["seek"] <= 0.5 * ratios["whole"]
    # extent sidecars exist next to the chunk files
    tgx = list((tmp_path / "seek").rglob("*.tgx"))
    assert tgx, "extent sidecars were not persisted"


def test_extent_sidecar_survives_reopen_and_tombstones(tmp_path):
    rng = np.random.RandomState(3)
    store = DeltaStore(m=1, r=1, backend="file", root=str(tmp_path))
    k1 = DeltaKey(0, 0, "S:0:0", 0)
    k2 = DeltaKey(0, 0, "S:0:1", 0)
    a1, a2 = _arrays(rng), _arrays(rng)
    store.put(k1, a1)
    store.put(k2, a2)
    store.delete(k2)
    # a fresh store over the same root: extents load from the sidecar
    re = DeltaStore(m=1, r=1, backend="file", root=str(tmp_path))
    out = re.get(k1)
    for k, v in a1.items():
        assert np.array_equal(out[k], v)
    with pytest.raises(KeyMissing):
        re.get(k2)  # tombstone honored through the sidecar
    # the reopened read never slurped the whole chunk file
    chunk = re._chunk_path(0, k1.placement)
    sidecar = re._extent_path(0, k1.placement).stat().st_size
    assert re.stats.bytes_io < chunk.stat().st_size + sidecar


def test_projection_saves_file_bytes_not_just_decode(tmp_path):
    """The wire-through of serialize's column offsets: a fields=
    projection on the seek backend reads ONLY the requested columns'
    byte ranges (plus the directory prefix)."""
    rng = np.random.RandomState(4)
    store = DeltaStore(m=1, r=1, backend="file", root=str(tmp_path),
                       pool_bytes=0)
    key = DeltaKey(0, 0, "S:0:0", 0)
    arrays = {"small": np.arange(100, dtype=np.int64),
              "huge": rng.randn(200_000).astype(np.float64)}
    store.put(key, arrays)
    store._ext_cache.clear()
    store.stats.reset()
    out = store.get(key, fields=["small"])
    assert list(out) == ["small"]
    # bytes read ≈ sidecar + directory prefix; the huge column's payload
    # (~1.6MB, zlib'd to >1MB) never crosses the disk interface
    assert store.stats.bytes_io < 64 << 10


# ---------------------------------------------------------------------------
# Per-column checksums
# ---------------------------------------------------------------------------


def _corrupt_payload(blob: bytes, col: str) -> bytes:
    meta = next(m for m in S.walk(blob) if m.name == col)
    assert meta.length > 0
    b = bytearray(blob)
    b[meta.off] ^= 0xFF
    return bytes(b)


def test_crc_mismatch_raises_clear_error():
    rng = np.random.RandomState(5)
    arrays = {"good": np.arange(300, dtype=np.int32),
              "bad": rng.randint(0, 10**6, 500).astype(np.int64)}
    blob = S.dumps(arrays, fmt="TGI2")
    corrupted = _corrupt_payload(blob, "bad")
    with pytest.raises(BlockCorruption, match="'bad'.*crc32"):
        S.loads(corrupted)
    # a projection that avoids the corrupted column still decodes
    out = S.loads(corrupted, fields=["good"])
    assert np.array_equal(out["good"], arrays["good"])


def test_legacy_precrc_tgi2_blob_still_loads():
    """The crc field was added under a directory version flag (high bit
    of the column count): blocks written by the pre-checksum writer —
    17-byte entry tails, flag clear — must keep loading unverified."""
    import io
    import struct

    rng = np.random.RandomState(17)
    arrays = {"t": np.sort(rng.randint(0, 10**6, 800)).astype(np.int64),
              "x": rng.randint(-1, 4, (100, 4)).astype(np.int32)}
    # re-implementation of the legacy writer (the old byte layout)
    cols = []
    dir_len = 8
    for name, arr in sorted(arrays.items()):
        enc, payload = S._encode_column(np.ascontiguousarray(arr), "size")
        nb = name.encode()
        cols.append((nb, arr, enc, payload))
        dir_len += 2 + len(nb) + 2 + 8 * arr.ndim + 17
    buf = io.BytesIO()
    buf.write(S.MAGIC2)
    buf.write(struct.pack("<I", len(cols)))  # no DIR_HAS_CRC flag
    off = dir_len
    for nb, arr, enc, payload in cols:
        buf.write(struct.pack("<H", len(nb)))
        buf.write(nb)
        buf.write(struct.pack("<BB", S._DT_CODE[np.dtype(arr.dtype)], arr.ndim))
        buf.write(struct.pack(f"<{arr.ndim}q", *arr.shape))
        buf.write(struct.pack("<BQQ", enc, len(payload), off))
        off += len(payload)
    for _, _, _, payload in cols:
        buf.write(payload)
    legacy = buf.getvalue()
    out = S.loads(legacy)
    for k, v in arrays.items():
        assert np.array_equal(out[k], v) and out[k].dtype == v.dtype, k
    assert all(i["crc"] is None for i in S.block_info(legacy).values())
    # and through a store (mixed-format read path)
    store = DeltaStore(m=1, r=1, backend="mem")
    key = DeltaKey(0, 0, "S:0:0", 0)
    store._mem[0][key] = legacy
    got = store.get(key, fields=["t"])
    assert np.array_equal(got["t"], arrays["t"])


def test_corrupt_replica_fails_over_to_healthy_copy(tmp_path):
    """r=2: a crc mismatch on the first replica must fail over to the
    intact copy, like a down node — not abort the read."""
    rng = np.random.RandomState(18)
    store = DeltaStore(m=2, r=2, backend="file", root=str(tmp_path))
    key = DeltaKey(0, 0, "S:0:0", 0)
    arrays = _arrays(rng)
    store.put(key, arrays)
    first = store.replicas(key)[0]
    path = store._chunk_path(first, key.placement)
    data = bytearray(path.read_bytes())
    rec_key = b"S:0:0|0"
    blob_off = data.index(rec_key) + len(rec_key) + 8
    meta = max(S.walk(bytes(data[blob_off:])), key=lambda m: m.length)
    data[blob_off + meta.off] ^= 0x55
    path.write_bytes(bytes(data))
    store.clear_pool()
    out = store.get(key)  # served by the second replica
    for k, v in arrays.items():
        assert np.array_equal(out[k], v)
    assert store.stats.failovers > 0


def test_pool_entry_immune_to_caller_mutation():
    """The pooled copy is independent: a caller mutating its cold-read
    array must not poison later reads."""
    store = DeltaStore(m=1, r=1, backend="mem")
    key = DeltaKey(0, 0, "S:0:0", 0)
    vals = np.arange(4000, dtype=np.int64) * 3  # narrow/delta-coded
    store.put(key, {"v": vals})
    got = store.get(key)["v"]
    if got.flags.writeable:
        got[:] = -1  # caller scribbles over its result
    warm = store.get(key)["v"]
    assert np.array_equal(warm, vals)


@pytest.mark.parametrize("seek", [False, True])
def test_corrupted_block_on_file_backend(tmp_path, seek):
    rng = np.random.RandomState(6)
    store = DeltaStore(m=1, r=1, backend="file",
                       root=str(tmp_path / f"s{seek}"), seek=seek)
    key = DeltaKey(0, 0, "S:0:0", 0)
    arrays = _arrays(rng)
    store.put(key, arrays)
    # flip one payload byte inside the chunk file
    path = store._chunk_path(0, key.placement)
    data = bytearray(path.read_bytes())
    rec_key = b"S:0:0|0"
    blob_off = data.index(rec_key) + len(rec_key) + 8
    blob = bytes(data[blob_off:])
    meta = max(S.walk(blob), key=lambda m: m.length)  # a real payload
    data[blob_off + meta.off] ^= 0x55
    path.write_bytes(bytes(data))
    store.clear_pool()
    with pytest.raises(BlockCorruption):
        store.get(key)


# ---------------------------------------------------------------------------
# Chunked event-log storage
# ---------------------------------------------------------------------------


def test_chunked_event_log_unit():
    events = generate(900, seed=8)
    log = ChunkedEventLog()
    for lo in range(0, 900, 300):
        log.append(events.take(slice(lo, lo + 300)))
    assert len(log) == 900 and log.n_segments == 3
    assert log.time_range() == events.time_range()  # no fold needed
    assert log.n_segments == 3
    flat = log.fold()
    assert log.n_segments == 1
    for c in ("t", "kind", "src", "dst", "key", "val"):
        assert np.array_equal(getattr(flat, c), getattr(events, c))
    log.append(events.take(slice(0, 10)))  # re-chunk after fold
    assert log.n_segments == 2 and len(log) == 910
    assert len(log.take(slice(900, 910))) == 10
    assert ChunkedEventLog().time_range() == (0, 0)


def test_ingest_appends_are_o1_until_read():
    """The O(total-history) memcpy per batch is gone: updates queue
    segments; the flat log folds once on read and on compact()."""
    events = generate(2400, seed=9)
    cfg = TGIConfig(**CFG)
    store = DeltaStore(m=2, r=1, backend="mem")
    tgi = TGI.build(events.take(slice(0, 800)), cfg, store)
    n0 = tgi._events.n_segments
    for lo in range(800, 2400, 400):
        tgi.update(events.take(slice(lo, lo + 400)))
    assert tgi._events.n_segments == n0 + 4  # nothing folded during ingest
    t = int(np.mean(events.time_range()))
    _states_equal(tgi.get_snapshot(t),
                  naive_state_at(events, t, cfg.n_attrs))  # fold-on-read
    tgi.compact()
    assert tgi._events.n_segments <= 1  # folded on compact


# ---------------------------------------------------------------------------
# Cost-based plan selection
# ---------------------------------------------------------------------------


def test_khop_auto_is_cost_based_and_correct():
    events, cfg, store, tgi = _build(n=3000, seed=11)
    t = int(np.mean(events.time_range()))
    hub = int(np.argmax(naive_state_at(events, t, cfg.n_attrs).degree()))
    for k in (1, 2):
        est = tgi.explain_k_hop(hub, t, k)
        assert est["snapshot_bytes"] > 0
        want = ("expand" if est["expand_bytes"] < est["snapshot_bytes"]
                else "snapshot" if est["expand_bytes"] > est["snapshot_bytes"]
                else ("expand" if k <= 2 else "snapshot"))
        assert est["method"] == want
        a = tgi.get_k_hop(hub, t, k, method="auto")
        b = tgi.get_k_hop(hub, t, k, method="snapshot")
        c = tgi.get_k_hop(hub, t, k, method="expand")
        _states_equal(a, b)
        _states_equal(a, c)


def test_khop_estimates_discount_pool_residency():
    events, cfg, store, tgi = _build(n=3000, seed=11)
    t = int(np.mean(events.time_range()))
    cold = tgi.estimate_fetch_cost(t)
    assert cold["physical_raw_bytes"] == cold["raw_bytes"] > 0
    tgi.get_snapshot(t)  # warms the pool with this span's blocks
    warm = tgi.estimate_fetch_cost(t)
    assert warm["raw_bytes"] == cold["raw_bytes"]  # logical size unchanged
    assert warm["physical_raw_bytes"] < cold["physical_raw_bytes"]


def test_fetch_stage_shared_across_plans():
    from repro.taf import HistoricalGraphStore
    from repro.taf.plan import PlanExecutor

    PlanExecutor.clear_fetch_cache()
    events, cfg, kv, tgi = _build(n=2500, seed=12)
    store = HistoricalGraphStore.from_tgi(tgi)
    t0g, t1g = events.time_range()
    t0 = int(t0g + 0.2 * (t1g - t0g))
    t1 = int(t0g + 0.9 * (t1g - t0g))
    r1 = store.nodes(t0, t1).timeslice(int((t0 + t1) // 2)).run()
    reads0 = kv.stats.reads
    # a different plan over the same interval: the fetch stage is shared
    r2 = store.nodes(t0, t1).timeslice(int(t0 + (t1 - t0) // 3)).run()
    assert kv.stats.reads == reads0  # zero new storage reads
    assert any("fetch-cache hit" in n for n in r2.notes)
    assert r2.cost == r1.cost  # logical cost replayed, not dropped
    # ingest invalidates: the next plan re-fetches fresh state
    later = EventLog.from_arrays(
        t=np.arange(t1g + 1, t1g + 51), kind=np.zeros(50, np.int8),
        src=np.arange(50, dtype=np.int32) + 10_000)
    store.update(later)
    # the epoch bump invalidated the shared operand (the snapshot LRU may
    # still legitimately serve the unchanged t0 snapshot underneath)
    r3 = store.nodes(t0, t1).timeslice(int((t0 + t1) // 2)).run()
    assert not any("fetch-cache hit" in n for n in r3.notes)


def test_fetch_pruning_overridden_when_selection_covers_all_parts():
    from repro.taf import HistoricalGraphStore

    events, cfg, kv, tgi = _build(n=2500, seed=12)
    store = HistoricalGraphStore.from_tgi(tgi)
    t0g, t1g = events.time_range()
    t0 = int(t0g + 0.2 * (t1g - t0g))
    snap = store.snapshot(t0)
    all_ids = snap.node_ids()  # every partition is covered
    r = store.nodes(t0, int(t1g)).filter(node_ids=all_ids).run()
    assert any("covers every partition" in n for n in r.notes)
