"""Batched replay engine: property tests against the reference per-event
loops (randomized event logs incl. NODE_DEL-clears-attrs and same-
timestamp orderings), the one-replay plan golden, batched snapshot
parity, and the executor's replay LRU."""
import numpy as np
import pytest

from repro.core.events import (
    EDGE_ADD,
    EDGE_DEL,
    NATTR_SET,
    NODE_ADD,
    NODE_DEL,
)
from repro.core.snapshot import pack_edge_key
from repro.data.temporal_graph_gen import generate
from repro.storage.kvstore import DeltaStore
from repro.taf import HistoricalGraphStore, TemporalQuery, operators as ops, replay
from repro.taf.son import SoTS


# ---------------------------------------------------------------------------
# Randomized operands (direct construction: full control over orderings)
# ---------------------------------------------------------------------------


def random_sots(rng, N=10, K=3, t_max=40, id_stride=3):
    """Random SoTS with adversarial structure: same-timestamp event runs,
    NODE_DEL / NATTR interleavings, edge events referencing both member
    and non-member ids, sparse node ids."""
    node_ids = np.sort(
        rng.choice(np.arange(N * id_stride), size=N, replace=False)
    ).astype(np.int32)
    init_present = (rng.rand(N) < 0.7).astype(np.int8)
    init_attrs = rng.randint(-1, 6, size=(N, K)).astype(np.int32)
    counts = rng.randint(0, 14, size=N)
    indptr = np.r_[0, np.cumsum(counts)].astype(np.int64)
    E = int(indptr[-1])
    ev_t = np.empty(E, np.int64)
    ev_kind = np.empty(E, np.int8)
    ev_key = np.full(E, -1, np.int16)
    ev_val = np.full(E, -1, np.int32)
    ev_other = np.full(E, -1, np.int32)
    other_pool = np.concatenate([node_ids, node_ids + 1])  # some non-members
    kinds_pool = [NODE_ADD, NODE_DEL, NATTR_SET, NATTR_SET, EDGE_ADD,
                  EDGE_ADD, EDGE_DEL]
    for i in range(N):
        lo, hi = int(indptr[i]), int(indptr[i + 1])
        n = hi - lo
        if not n:
            continue
        tt = np.sort(rng.randint(0, t_max, size=n))
        # force same-timestamp runs: collapse random adjacent gaps
        for j in range(1, n):
            if rng.rand() < 0.4:
                tt[j] = tt[j - 1]
        ev_t[lo:hi] = np.sort(tt)
        ev_kind[lo:hi] = rng.choice(kinds_pool, size=n)
        ev_key[lo:hi] = rng.randint(0, K, size=n)
        ev_val[lo:hi] = rng.randint(0, 9, size=n)
        ev_other[lo:hi] = rng.choice(other_pool, size=n)
    # initial adjacency: sorted unique neighbors per center
    adj_counts = rng.randint(0, 4, size=N)
    adj_indptr = np.r_[0, np.cumsum(adj_counts)].astype(np.int64)
    adj_nbr = np.empty(int(adj_indptr[-1]), np.int32)
    for i in range(N):
        lo, hi = int(adj_indptr[i]), int(adj_indptr[i + 1])
        if hi > lo:
            adj_nbr[lo:hi] = np.sort(
                rng.choice(other_pool, size=hi - lo, replace=False))
    return SoTS(
        node_ids=node_ids, t0=0, t1=t_max,
        init_present=init_present, init_attrs=init_attrs,
        ev_indptr=indptr, ev_t=ev_t, ev_kind=ev_kind, ev_key=ev_key,
        ev_val=ev_val, ev_other=ev_other,
        adj_indptr=adj_indptr, adj_nbr=adj_nbr,
        adj_val=np.full(len(adj_nbr), -1, np.int32),
    )


# ---------------------------------------------------------------------------
# state_at_many == _state_at_ref column-by-column
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_state_at_many_matches_reference_loop(seed):
    rng = np.random.RandomState(seed)
    sots = random_sots(rng)
    # unsorted, duplicated, and out-of-range timepoints
    ts = rng.randint(-5, 50, size=13).astype(np.int64)
    ts[3] = ts[7]
    present, attrs = replay.state_at_many(sots, ts)
    assert present.shape == (len(sots), len(ts))
    assert attrs.shape == (len(sots), len(ts), sots.init_attrs.shape[1])
    for j, t in enumerate(ts):
        p_ref, a_ref = ops._state_at_ref(sots, int(t))
        np.testing.assert_array_equal(present[:, j], p_ref, err_msg=f"t={t}")
        np.testing.assert_array_equal(attrs[:, j], a_ref, err_msg=f"t={t}")


def test_state_at_many_delete_clears_then_rewrite_batched():
    """The NODE_DEL-clears-all-attrs + same-timestamp NATTR resurrection
    ordering, evaluated at every timepoint in one batch."""
    son = SoTS(
        node_ids=np.asarray([0, 1], np.int32), t0=0, t1=10,
        init_present=np.asarray([1, 1], np.int8),
        init_attrs=np.asarray([[5, 6], [7, 8]], np.int32),
        ev_indptr=np.asarray([0, 3, 5], np.int64),
        ev_t=np.asarray([1, 2, 2, 2, 2], np.int64),
        ev_kind=np.asarray([NODE_DEL, NATTR_SET, NATTR_SET,
                            NODE_DEL, NATTR_SET], np.int8),
        ev_key=np.asarray([-1, 0, 1, -1, 0], np.int16),
        ev_val=np.asarray([-1, 9, 11, -1, 4], np.int32),
        ev_other=np.full(5, -1, np.int32),
        adj_indptr=np.zeros(3, np.int64),
        adj_nbr=np.empty(0, np.int32), adj_val=np.empty(0, np.int32),
    )
    ts = np.asarray([0, 1, 2, 3, 10], np.int64)
    present, attrs = replay.state_at_many(son, ts)
    for j, t in enumerate(ts):
        p_ref, a_ref = ops._state_at_ref(son, int(t))
        np.testing.assert_array_equal(present[:, j], p_ref)
        np.testing.assert_array_equal(attrs[:, j], a_ref)


# ---------------------------------------------------------------------------
# EdgeReplay == the per-event set-replay loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_neighbors_at_matches_reference_loop(seed):
    rng = np.random.RandomState(100 + seed)
    sots = random_sots(rng)
    ts = (-1, 0, 7, 20, 39, 45)
    for t in ts:
        for i in range(len(sots)):
            want = ops._neighbors_at_ref(sots, i, t)
            got = ops.neighbors_at(sots, i, t)
            np.testing.assert_array_equal(got, want, err_msg=f"i={i} t={t}")
    # and the batched per-center form over the shared table
    for i in range(len(sots)):
        many = replay.neighbors_at_many(sots, i, ts)
        for t, got in zip(ts, many):
            np.testing.assert_array_equal(got, ops._neighbors_at_ref(sots, i, t))


@pytest.mark.parametrize("seed", range(4))
def test_degree_series_matches_neighbor_counts(seed):
    rng = np.random.RandomState(200 + seed)
    sots = random_sots(rng)
    ts = np.asarray([0, 5, 17, 39], np.int64)
    deg = replay.degree_series(sots, ts)
    for j, t in enumerate(ts):
        for i in range(len(sots)):
            assert deg[i, j] == len(ops._neighbors_at_ref(sots, i, int(t)))


@pytest.mark.parametrize("seed", range(4))
def test_graph_matches_reference_construction(seed):
    """graph() on the CSR path == the old per-node set-loop construction
    (present centers, members-only edges, canonical packed keys)."""
    rng = np.random.RandomState(300 + seed)
    sots = random_sots(rng)
    for t in (0, 11, 39):
        g = ops.graph(sots, t)
        present, _ = ops._state_at_ref(sots, t)
        member = set(int(x) for x in sots.node_ids)
        keys = []
        for i in range(len(sots)):
            if not present[i]:
                continue
            u = int(sots.node_ids[i])
            for v in ops._neighbors_at_ref(sots, i, t):
                if int(v) in member:
                    keys.append(pack_edge_key([min(u, int(v))],
                                              [max(u, int(v))])[0])
        want = np.unique(np.asarray(keys, np.int64)) if keys else \
            np.empty(0, np.int64)
        np.testing.assert_array_equal(g.edge_key, want)
        np.testing.assert_array_equal(g.present[sots.node_ids], present)


def test_pack_edge_key_guards_range():
    with pytest.raises(ValueError):
        pack_edge_key([2**31], [0])
    with pytest.raises(ValueError):
        pack_edge_key([0], [-1])
    # distinct pairs stay distinct near the boundary (the old arithmetic
    # pack collided once dst crossed 2^31)
    k = pack_edge_key([1, 2], [2**31 - 1, 0])
    assert len(np.unique(k)) == 2


# ---------------------------------------------------------------------------
# Vectorized delta fold == scalar fold
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(4))
def test_vectorized_delta_fold_matches_scalar(seed):
    rng = np.random.RandomState(400 + seed)
    sots = random_sots(rng)
    pts = np.asarray([3, 9, 9, 21, 39], np.int64)

    def f_s(present, attrs, son, i, init):
        deg = son.adj_indptr[i + 1] - son.adj_indptr[i]
        return None, float(deg if present else 0)

    def fd_s(aux, val, kind, key, val_, other, i, son):
        if kind == EDGE_ADD:
            return aux, val + 1.0
        if kind == EDGE_DEL:
            return aux, val - 1.0
        return aux, val

    def f_v(present, attrs, son, init, **kw):
        deg = (son.adj_indptr[1:] - son.adj_indptr[:-1]).astype(np.float64)
        return None, np.where(present == 1, deg, 0.0)

    def fd_v(aux, val, node, kind, son, **kw):
        np.add.at(val, node[kind == EDGE_ADD], 1.0)
        np.add.at(val, node[kind == EDGE_DEL], -1.0)
        return aux, val

    f_v.vectorized = True
    fd_v.vectorized = True
    ts_s, out_s = ops.node_compute_delta(sots, f_s, fd_s, points=pts)
    ts_v, out_v = ops.node_compute_delta(sots, f_v, fd_v, points=pts)
    np.testing.assert_array_equal(ts_s, ts_v)
    np.testing.assert_allclose(out_s, out_v)


# ---------------------------------------------------------------------------
# Plan integration: one replay per multi-timepoint plan + the LRU
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def store_setup():
    events = generate(3000, seed=11)
    store = HistoricalGraphStore.build(
        events, n_shards=2, parts_per_shard=2, events_per_span=900,
        eventlist_size=128, checkpoints_per_span=3,
        store=DeltaStore(m=2, r=1, backend="mem"))
    t0g, t1g = store.time_range()
    t0 = int(t0g + 0.3 * (t1g - t0g))
    t1 = int(t0g + 0.8 * (t1g - t0g))
    return store, t0, t1


def test_multi_ts_plan_issues_exactly_one_replay(store_setup):
    store, t0, t1 = store_setup
    ts = [t0, (t0 + t1) // 2, t1]
    q = store.nodes(t0, t1).timeslice(ts)
    before = replay.STATS["state_at_many"]
    out = q.execute()
    assert replay.STATS["state_at_many"] - before == 1
    assert out["present"].shape[1] == len(ts)
    # and a temporal compute over pinned points batches the same way
    def f(present, attrs, son, t, **kw):
        return present.astype(np.float64)

    f.vectorized = True
    before = replay.STATS["state_at_many"]
    store.nodes(t0, t1).timeslice(ts).node_compute(f, style="temporal").execute()
    assert replay.STATS["state_at_many"] - before == 1


def test_repeated_slice_hits_executor_lru(store_setup):
    store, t0, t1 = store_setup
    sots = store.subgraphs(t0, t1).materialize()
    ts = [t0, t1]
    before = replay.STATS["state_at_many"]
    a = sots.timeslice(ts).execute()
    b = sots.timeslice(ts).execute()
    assert replay.STATS["state_at_many"] - before == 1  # second is an LRU hit
    np.testing.assert_array_equal(a["present"], b["present"])


def test_replay_cache_rejects_recycled_operand_identity():
    """An LRU entry must die with its operand: id() recycling after gc
    must not serve operand A's states for a different operand B."""
    cache = replay.ReplayCache(maxsize=4)

    def make(val):
        return SoTS(
            node_ids=np.asarray([0], np.int32), t0=0, t1=10,
            init_present=np.asarray([1], np.int8),
            init_attrs=np.asarray([[val]], np.int32),
            ev_indptr=np.asarray([0, 0], np.int64),
            ev_t=np.empty(0, np.int64), ev_kind=np.empty(0, np.int8),
            ev_key=np.empty(0, np.int16), ev_val=np.empty(0, np.int32),
            ev_other=np.empty(0, np.int32),
            adj_indptr=np.zeros(2, np.int64),
            adj_nbr=np.empty(0, np.int32), adj_val=np.empty(0, np.int32),
        )

    a = make(111)
    key_a = (replay.operand_key(a), ("scalar", 5))
    cache.put(key_a, {"attrs": a.init_attrs}, owner=a)
    assert cache.get(key_a, owner=a) is not None
    del a  # operand dies; its address may be recycled by the next alloc
    b = make(222)
    key_b = (replay.operand_key(b), ("scalar", 5))
    hit = cache.get(key_b, owner=b)
    assert hit is None or hit["attrs"][0, 0] == 222


def test_cached_slice_results_are_mutation_safe(store_setup):
    """Mutating an executed timeslice result must not poison the LRU."""
    store, t0, t1 = store_setup
    q = store.nodes(t0, t1).materialize()
    ts = [t0, (t0 + t1) // 2]
    first = q.timeslice(ts).execute()
    want = first["present"].copy()
    first["present"][:] = -7
    again = q.timeslice(ts).execute()
    np.testing.assert_array_equal(again["present"], want)


def test_get_snapshots_does_not_pollute_single_snapshot_cost(store_setup):
    """Batch members share one fetch; a later single get_snapshot must
    report its own exact logical cost, not the group's."""
    store, t0, t1 = store_setup
    tgi = store.tgi
    ts = np.linspace(t0, t1, 5).astype(np.int64).tolist()
    tgi.invalidate_caches()
    tgi.get_snapshot(int(ts[0]))
    cold = tgi.last_cost.n_deltas
    tgi.invalidate_caches()
    tgi.get_snapshots(ts)
    tgi.get_snapshot(int(ts[0]))  # after the batch: same accounting
    assert tgi.last_cost.n_deltas == cold


def test_timeslice_multi_matches_scalar_slices(store_setup):
    store, t0, t1 = store_setup
    son = store.nodes(t0, t1).materialize().operand
    ts = np.linspace(t0 - 1, t1 + 1, 7).astype(np.int64)
    sl = ops.timeslice(son, ts)
    for j, t in enumerate(ts):
        single = ops.timeslice(son, int(t))
        np.testing.assert_array_equal(sl["present"][:, j], single["present"])
        np.testing.assert_array_equal(sl["attrs"][:, j], single["attrs"])


# ---------------------------------------------------------------------------
# Batched snapshot retrieval (TGI.get_snapshots)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_kernel", [False, True])
def test_get_snapshots_matches_single_snapshots(store_setup, use_kernel):
    store, t0, t1 = store_setup
    tgi = store.tgi
    ts = np.linspace(t0, t1, 5).astype(np.int64).tolist()
    tgi.invalidate_caches()
    want = []
    for t in ts:
        tgi.invalidate_caches()
        want.append(tgi.get_snapshot(int(t)))
    tgi.invalidate_caches()
    got = tgi.get_snapshots(ts, use_kernel=use_kernel)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g.present, w.present)
        np.testing.assert_array_equal(g.attrs, w.attrs)
        np.testing.assert_array_equal(g.edge_key, w.edge_key)


def test_get_snapshots_shares_fetches(store_setup):
    """Timepoints under one (span, checkpoint) group must not re-pay the
    hierarchy path per t: the batch costs less than T singles."""
    store, t0, t1 = store_setup
    tgi = store.tgi
    ts = np.linspace(t0, t1, 6).astype(np.int64).tolist()
    singles = 0
    for t in ts:
        tgi.invalidate_caches()
        tgi.get_snapshot(int(t))
        singles += tgi.last_cost.n_deltas
    tgi.invalidate_caches()
    tgi.get_snapshots(ts)
    assert tgi.last_cost.n_deltas < singles


def test_snapshot_cache_replays_logical_cost(store_setup):
    store, t0, t1 = store_setup
    tgi = store.tgi
    tm = (t0 + t1) // 2
    tgi.invalidate_caches()
    g1 = tgi.get_snapshot(tm)
    cost1 = (tgi.last_cost.n_deltas, tgi.last_cost.n_bytes)
    reads = store.store.stats.reads
    g2 = tgi.get_snapshot(tm)  # LRU hit: no storage reads, same accounting
    assert store.store.stats.reads == reads
    assert (tgi.last_cost.n_deltas, tgi.last_cost.n_bytes) == cost1
    np.testing.assert_array_equal(g1.present, g2.present)
    np.testing.assert_array_equal(g1.edge_key, g2.edge_key)
    g2.present[:] = 0  # cached copies must not alias
    assert tgi.get_snapshot(tm).present.sum() == g1.present.sum()


# ---------------------------------------------------------------------------
# Aggregation fix: sign-aware saturate
# ---------------------------------------------------------------------------


def test_saturate_sign_aware():
    pos = np.asarray([0.0, 0.5, 0.96, 1.0])
    assert ops.temp_aggregate(pos, "saturate") == 2
    neg = -pos  # e.g. a difference series from compare()
    assert ops.temp_aggregate(neg, "saturate") == 2
    # the old >= 0.95*final test would return 0 here
    drift = np.asarray([-0.1, -0.4, -0.97, -1.0])
    assert ops.temp_aggregate(drift, "saturate") == 2


# ---------------------------------------------------------------------------
# Device parity: time-batched degree kernel
# ---------------------------------------------------------------------------


def test_sharded_degree_series_matches_replay(store_setup):
    from repro.taf import exec as taf_exec

    store, t0, t1 = store_setup
    sots = store.subgraphs(t0, t1).materialize().operand
    ts = np.linspace(t0, t1, 4).astype(np.int64)
    got = taf_exec.sharded_degree_series(sots, ts)
    want = replay.degree_series(sots, ts)
    on = sots.init_present == 1
    np.testing.assert_array_equal(got[on], want[on])
