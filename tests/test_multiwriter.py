"""Lease-fenced multi-writer write plane.

Families:

* seeded interleave property tests — 2-3 writers' ``(epoch, seq)``
  lanes applied to replicas in shuffled arrival orders must converge
  (after a canonical vacuum) to files byte-identical to a single-order
  oracle replay, with per-key winners = max combined vseq;
* live multi-writer convergence — concurrent ``RemoteDeltaStore``
  writers under distinct lease epochs against one cluster, verified
  against the union of their acked-op logs;
* fencing — a lane force-sealed under a live writer turns that
  writer's next write into a typed ``LeaseFenced`` (never applied),
  and the writer recovers under a fresh epoch;
* quorum loss — writes degrade to fast typed ``WriteUnavailable``
  while reads keep failing over, and the writer re-acquires
  automatically once a quorum returns;
* the stranded-seq regression — a SIGKILLed writer process freezes
  its lane's ack watermark (feed truncation starves) until orphan-seq
  reconciliation seals the lane and coverage advances past it, with
  zero acked writes lost;
* mid-reconcile crash points (``cell.reconcile``) — an aborted
  reconciliation leaves nothing sealed and a retry converges;
* shared-secret wire auth — wrong/missing keys and fuzzed MACs are
  rejected with the typed ``AuthFailed`` and a closed connection.

``REPRO_SEED_OFFSET`` shifts every schedule's seed so CI's stress job
runs the same suite under genuinely distinct interleavings.
"""
import hashlib
import os
import socket
import struct
import subprocess
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import faultpoints
from repro.service import (AuthFailed, ClusterSpec, LeaseFenced,
                           LocalCluster, StorageCell, WriteUnavailable)
from repro.service import wire
from repro.service.client import RemoteDeltaStore
from repro.service.stress import (encode_token, key_for, payload_arrays,
                                  read_acked_log)
from repro.storage.kvstore import (KeyMissing, StorageNodeDown, make_vseq,
                                   replica_nodes, split_vseq)

SEED_OFFSET = int(os.environ.get("REPRO_SEED_OFFSET", "0"))
HOST = "127.0.0.1"


def _lane_stream(epoch, n_ops, keyspace, seed):
    """One writer's deterministic (epoch, seq) record stream over the
    shared keyspace: PUTs with seeded payload tokens, every 7th op a
    DELETE.  Token = epoch * 100_000 + seq, so the oracle can rebuild
    any record's payload from its vseq alone."""
    rng = np.random.RandomState(seed)
    recs = []
    for s in range(1, n_ops + 1):
        key = key_for(int(rng.randint(0, keyspace)))
        if s % 7 == 0:
            recs.append(wire.FeedRecord(make_vseq(epoch, s),
                                        wire.OP_DELETE, key, 0, b""))
        else:
            blob, raw = encode_token(key, epoch * 100_000 + s)
            recs.append(wire.FeedRecord(make_vseq(epoch, s),
                                        wire.OP_PUT, key, raw, blob))
    return recs


def _matches(got, token):
    want = payload_arrays(token)
    return (set(got) == set(want)
            and all(np.array_equal(got[f], want[f]) for f in want))


# ---------------------------------------------------------------------------
# seeded interleave property tests vs a single-order oracle
# ---------------------------------------------------------------------------


@pytest.mark.timeout(180)
@pytest.mark.parametrize("seed", [101, 211, 307])
def test_interleaved_lanes_converge_to_single_order_oracle(tmp_path, seed):
    """Three lanes' streams, delivered to each replica in a different
    shuffled order, must land every replica on the SAME state as an
    oracle that applied the merged stream in vseq order — per-key
    winners AND (after a canonical vacuum) chunk/extent file bytes."""
    seed += SEED_OFFSET
    lanes = [_lane_stream(e, 40, 10, seed * 7 + e) for e in (1, 2, 3)]
    recs = [r for lane in lanes for r in lane]
    order = sorted(recs, key=lambda r: r.seq)

    def build(root, node, sequence):
        cell = StorageCell(node_id=node, n_cells=2, r=2, backend="file",
                           root=str(root), feed_keep=10**6)
        for r in sequence:
            cell.apply(r)
        cell.store.vacuum(canonical=True)
        return cell

    def hashes(root):
        return {str(p.relative_to(root)):
                hashlib.sha256(p.read_bytes()).hexdigest()
                for p in sorted(Path(root).rglob("*"))
                if p.is_file() and p.suffix in (".tgi", ".tgx")}

    rng = np.random.RandomState(seed)
    winners = {}
    for r in order:
        winners[r.key] = r
    for node in range(2):
        shuffled = list(recs)
        rng.shuffle(shuffled)
        cell = build(tmp_path / f"shuf{node}", node, shuffled)
        oracle = build(tmp_path / f"oracle{node}", node, order)
        assert cell._key_seq == oracle._key_seq
        assert cell._lane_seq == oracle._lane_seq == {1: 40, 2: 40, 3: 40}
        got_h = hashes(tmp_path / f"shuf{node}")
        assert got_h and got_h == hashes(tmp_path / f"oracle{node}")
        for key, r in winners.items():
            e, s = split_vseq(r.seq)
            if r.op == wire.OP_PUT:
                assert _matches(cell.store.get(key), e * 100_000 + s), key
            else:
                with pytest.raises(KeyMissing):
                    cell.store.get(key)


def test_fence_check_rejects_stale_epoch_write():
    """The cell-level gate: a write above a lane's seal is refused with
    the typed LeaseFenced; at-or-below the seal is a dup/gap-fill, and
    the legacy lane 0 is never fenced."""
    cell = StorageCell(node_id=0, n_cells=1, r=1, backend="mem")
    key = key_for(0)
    blob, raw = encode_token(key, 1)
    cell.apply(wire.FeedRecord(make_vseq(3, 1), wire.OP_PUT, key, raw, blob))
    cell.apply_seal(3, 1)
    with pytest.raises(LeaseFenced):
        cell.fence_check(make_vseq(3, 2), "stale-writer")
    cell.fence_check(make_vseq(3, 1), "stale-writer")  # dup: dedupe's job
    cell.fence_check(make_vseq(0, 5))  # legacy single-writer lane
    assert cell.fenced_writes == 1


@pytest.mark.timeout(120)
def test_mid_reconcile_crash_leaves_lane_open_and_retry_converges(tmp_path):
    """cell.reconcile fires after anti-entropy, before the seal
    persists: an aborted pass must seal NOTHING anywhere, and a clean
    retry seals both replicas at the merged high-water mark, resuming
    feed truncation past the dead lane."""
    b = StorageCell(node_id=1, n_cells=2, r=2, backend="file",
                    root=str(tmp_path / "b"), feed_keep=4)
    b.start()
    a = StorageCell(node_id=0, n_cells=2, r=2, backend="file",
                    root=str(tmp_path / "a"), feed_keep=4)
    a.start(peers=[(HOST, b.port)])
    try:
        recs = _lane_stream(1, 24, 8, 5 + SEED_OFFSET)
        for i, r in enumerate(recs):
            if i % 5 != 3:  # a missed some of the dead writer's records
                a.apply(r)
            if i % 5 != 1:  # ...and b missed a different subset
                b.apply(r)
        with faultpoints.scoped("cell.reconcile", 1, "raise"):
            with pytest.raises(faultpoints.FaultError):
                a.reconcile_lane(1)
        assert a._sealed.get(1) is None and b._sealed.get(1) is None
        assert a.reconcile_lane(1) is True
        assert a._sealed[1] == b._sealed[1] == 24
        assert a._lane_seq[1] == b._lane_seq[1] == 24  # anti-entropied
        assert a._key_seq == b._key_seq
        # coverage advanced past the dead lane: truncation resumed
        assert a._floors[1] == b._floors[1] == 24
    finally:
        a.stop()
        b.stop()


# ---------------------------------------------------------------------------
# live clusters: concurrent writers, fencing, quorum loss
# ---------------------------------------------------------------------------


@pytest.mark.timeout(180)
def test_three_concurrent_writers_converge_on_max_vseq_winners(tmp_path):
    """Three leased writers hammer overlapping keys through one thread
    cluster; afterwards every key serves the max-(epoch, seq) winner
    across the union of the writers' acked-op logs."""
    seed = 5 + SEED_OFFSET
    spec = ClusterSpec(n_cells=3, r=2, backend="file",
                       root=str(tmp_path / "cluster"), lease_ttl=5.0)
    with LocalCluster(spec, mode="thread") as cl:
        logs, errs = {}, []

        def work(wseed):
            rng = np.random.default_rng(wseed)
            st = cl.client(timeout=5.0, pool_bytes=0,
                           writer_id=f"w{wseed}")
            rows = []
            try:
                for i in range(60):
                    key = key_for(int(rng.integers(0, 10)))
                    token = wseed * 1_000_003 + i
                    if i % 10 == 9:
                        st.delete(key)
                        token = 0
                    else:
                        blob, raw = encode_token(key, token)
                        st.put_encoded(key, blob, raw)
                    ls = st.lease_status()
                    rows.append(("DEL" if not token else "PUT", key,
                                 make_vseq(ls["epoch"], ls["seq"]), token))
                st.quiesce()
            except Exception as exc:  # surfaced to the main thread
                errs.append((wseed, repr(exc)))
            finally:
                st.close()
            logs[wseed] = rows

        threads = [threading.Thread(target=work, args=(seed * 10 + j,))
                   for j in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs, errs
        epochs = {split_vseq(rows[0][2])[0] for rows in logs.values()}
        assert len(epochs) == 3  # every writer got its own lane
        winners = {}
        for rows in logs.values():
            for op, key, vseq, token in rows:
                if key not in winners or vseq > winners[key][1]:
                    winners[key] = (op, vseq, token)
        reader = cl.client(timeout=5.0, pool_bytes=0)
        for key, (op, vseq, token) in winners.items():
            if op == "PUT":
                assert _matches(reader.get(key), token), key
            else:
                with pytest.raises(KeyMissing):
                    reader.get(key)
        reader.close()


@pytest.mark.timeout(60)
def test_stale_writer_fenced_after_forced_reconcile(tmp_path):
    """Force-sealing a live writer's lane turns its next write into a
    typed LeaseFenced — the write is never applied — and the fenced
    writer transparently recovers under a fresh epoch."""
    spec = ClusterSpec(n_cells=3, r=2, backend="file",
                       root=str(tmp_path / "cluster"), lease_ttl=30.0)
    with LocalCluster(spec, mode="thread") as cl:
        w = cl.client(timeout=2.0, pool_bytes=0)
        ops = cl.client(timeout=2.0, pool_bytes=0)
        key = key_for(0)
        w.put(key, payload_arrays(1))
        epoch = w.lease_status()["epoch"]
        seal = ops.reconcile_lane(epoch, force=True)  # the stale drill
        assert seal >= 1
        with pytest.raises(LeaseFenced):
            w.put(key, payload_arrays(2))
        assert _matches(ops.get(key), 1)  # fenced write left no trace
        w.put(key, payload_arrays(3))  # re-acquires a fresh lane
        assert w.lease_status()["epoch"] > epoch
        assert w.stats.lease_fenced >= 1
        assert _matches(ops.get(key), 3)
        w.close()
        ops.close()


@pytest.mark.timeout(120)
def test_quorum_loss_degrades_then_auto_recovers(tmp_path):
    """Killing 2/3 cells starves lease renewal: writes degrade to a
    fast typed WriteUnavailable while reads keep serving from the
    survivor; restoring the quorum re-acquires automatically under a
    fresh epoch with no client restart."""
    spec = ClusterSpec(n_cells=3, r=2, backend="file",
                       root=str(tmp_path / "cluster"), lease_ttl=0.5)
    # a slot whose replica chain includes the surviving cell 0
    slot = next(s for s in range(8)
                if 0 in replica_nodes(7, s % 2, 3, 2))
    key = key_for(slot)
    with LocalCluster(spec, mode="thread") as cl:
        w = cl.client(timeout=0.5, retries=0, backoff=0.01, pool_bytes=0)
        w.put(key, payload_arrays(10))
        epoch0 = w.lease_status()["epoch"]
        cl.kill(1)
        cl.kill(2)
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline:
            try:
                w.put(key, payload_arrays(11))
            except WriteUnavailable:
                break
            except StorageNodeDown:
                pass  # replica set fully dark for this op: keep going
            time.sleep(0.05)
        else:
            pytest.fail("writes kept succeeding without a renew quorum")
        assert "src" in w.get(key)  # reads fail over to the survivor
        t0 = time.monotonic()
        with pytest.raises(WriteUnavailable):  # degraded -> fail FAST
            w.put(key, payload_arrays(12))
        assert time.monotonic() - t0 < 0.5
        cl.restart(1)
        cl.restart(2)
        w._suspects.clear()
        deadline = time.monotonic() + 20
        while True:  # the background lease loop re-acquires on its own
            try:
                w.put(key, payload_arrays(13))
                break
            except (WriteUnavailable, StorageNodeDown):
                if time.monotonic() > deadline:
                    raise
                time.sleep(0.1)
        st = w.lease_status()
        assert st["epoch"] > epoch0 and not st["degraded"]
        assert _matches(w.get(key), 13)
        w.close()


# ---------------------------------------------------------------------------
# the stranded-seq regression: SIGKILLed writer process
# ---------------------------------------------------------------------------


def _spawn_writer(cl, seed, out, n_writes=100_000, keyspace=12,
                  lease_ttl=1.0):
    import repro
    src = str(Path(next(iter(repro.__path__))).parent)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
                 if p])
    addrs = ",".join(f"{h}:{p}" for h, p in cl.addrs)
    cmd = [sys.executable, "-m", "repro.service.stress",
           "--addrs", addrs, "--r", str(cl.spec.r),
           "--n-writes", str(n_writes), "--keyspace", str(keyspace),
           "--seed", str(seed), "--out", str(out),
           "--lease-ttl", str(lease_ttl)]
    proc = subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.DEVNULL, text=True)
    line = proc.stdout.readline()
    assert line.startswith("WRITER READY"), line
    return proc


def _wait_lines(path, n, timeout=60.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if path.exists() and len(path.read_text().splitlines()) >= n:
            return
        time.sleep(0.05)
    raise TimeoutError(f"writer log never reached {n} acked ops")


@pytest.mark.timeout(300)
def test_sigkilled_writer_strands_ack_until_reconciliation(tmp_path):
    """The stranded-seq bug, regression-tested end to end: SIGKILL a
    real writer process mid-storm.  Its lane's ack watermark freezes
    (the pre-fix symptom: feed truncation starves behind the dead
    lane's coverage), until lease expiry triggers orphan-seq
    reconciliation — the lane seals at the max replica-acked record,
    coverage advances past it, truncation resumes, and every acked
    write is still served."""
    seed = 1 + SEED_OFFSET
    keyspace = 12
    spec = ClusterSpec(n_cells=3, r=2, backend="file",
                       root=str(tmp_path / "cluster"), feed_keep=8,
                       lease_ttl=1.0)
    with LocalCluster(spec, mode="subprocess") as cl:
        log = tmp_path / "writer.log"
        proc = _spawn_writer(cl, seed, log, keyspace=keyspace)
        try:
            _wait_lines(log, 40)
        finally:
            proc.kill()  # SIGKILL: no release, no goodbye
            proc.wait(timeout=10)
        rows = read_acked_log(log)
        assert len(rows) >= 40
        epoch = split_vseq(rows[-1][2])[0]
        max_acked = max(split_vseq(v)[1] for _, _, v, _ in rows)
        reader = cl.client(timeout=2.0, retries=1, backoff=0.02,
                           pool_bytes=0)
        # the stranded state: lane un-sealed, ack water frozen short of
        # the lane's high-water mark on every reporting cell
        frozen = {}
        for i, st in enumerate(reader.feed_status()):
            lane = (st or {}).get("lanes", {}).get(str(epoch))
            if lane is None:
                continue
            assert lane["seal"] is None
            frozen[i] = st["ack_water"]
        assert frozen
        # lease expiry (1s) + sweep (ttl/2) -> reconciliation seals it
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            lanes = [(st or {}).get("lanes", {}).get(str(epoch))
                     for st in reader.feed_status()]
            lanes = [l for l in lanes if l]
            if len(lanes) == 3 and all(l["seal"] is not None
                                       for l in lanes):
                break
            time.sleep(0.25)
        else:
            pytest.fail("dead lane never sealed by the sweeper")
        assert all(l["seal"] >= max_acked for l in lanes)
        # one agreed seal everywhere (lane seqs may differ per cell —
        # each only holds the placements it replicates)
        assert len({l["seal"] for l in lanes}) == 1
        # coverage advanced past the dead lane; truncation resumes
        reader.quiesce(truncate=True)
        for i, st in enumerate(reader.feed_status()):
            assert st is not None
            lane = st["lanes"][str(epoch)]
            assert lane["floor"] == lane["seal"] and not lane["lease"]
            assert st["ack_water"] >= make_vseq(epoch, max_acked)
            if i in frozen:
                assert st["ack_water"] > frozen[i]
        # zero acked writes lost: every key serves its max-vseq acked
        # winner — or the writer's single possibly-in-flight next op
        # (killed after the cluster applied it, before the log landed),
        # which reconciliation replicated everywhere
        n_acked = len(rows)
        rng = np.random.default_rng(seed)
        slots = [int(rng.integers(0, keyspace))
                 for _ in range(n_acked + 1)]
        cand_key = key_for(slots[n_acked])
        cand_op = "DEL" if n_acked % 10 == 9 else "PUT"
        cand_token = seed * 1_000_003 + n_acked
        winners = {}
        for op, key, vseq, token in rows:
            if key not in winners or vseq > winners[key][1]:
                winners[key] = (op, vseq, token)
        for key, (op, vseq, token) in winners.items():
            cand = key == cand_key
            try:
                got = reader.get(key)
            except KeyMissing:
                assert op == "DEL" or (cand and cand_op == "DEL"), key
                continue
            ok = op == "PUT" and _matches(got, token)
            if cand and cand_op == "PUT":
                ok = ok or _matches(got, cand_token)
            assert ok, key
        reader.close()


# ---------------------------------------------------------------------------
# shared-secret wire auth
# ---------------------------------------------------------------------------


@pytest.mark.timeout(60)
def test_wire_auth_accepts_key_and_rejects_typed(tmp_path):
    """ClusterSpec(auth_key=...) flows to every cell and client; a
    wrong or missing key is a typed AuthFailed — never wrapped into
    NodeUnavailable, never retried into a hang."""
    spec = ClusterSpec(n_cells=2, r=2, backend="file",
                       root=str(tmp_path / "cluster"),
                       auth_key="open-sesame")
    with LocalCluster(spec, mode="thread") as cl:
        w = cl.client(timeout=2.0, pool_bytes=0)
        key = key_for(2)
        w.put(key, payload_arrays(9))
        assert _matches(w.get(key), 9)
        w.close()
        for bad_key in ("wrong-key", None):
            bad = RemoteDeltaStore(cl.addrs, r=2, timeout=1.0, retries=2,
                                   backoff=0.01, pool_bytes=0,
                                   auth_key=bad_key)
            t0 = time.monotonic()
            with pytest.raises(AuthFailed):
                bad.get(key)
            assert time.monotonic() - t0 < 1.0  # typed, not retried
            bad.close()


@pytest.mark.timeout(60)
def test_wire_auth_fuzzed_macs_rejected_and_connection_closed():
    """Fuzz the HELLO challenge: random MACs (including empty and
    oversized) and skipped-auth requests all get ERR_AUTH_FAILED and a
    closed connection; the cell stays healthy for the right key."""
    cell = StorageCell(node_id=0, n_cells=1, r=1, backend="mem",
                       auth_key="k3y")
    cell.start()
    try:
        rng = np.random.RandomState(7 + SEED_OFFSET)
        for i in range(20):
            with socket.create_connection((HOST, cell.port),
                                          timeout=5) as s:
                s.settimeout(5)
                wire.send_frame(s, wire.MSG_HELLO, 1)
                chal = wire.recv_frame(s)
                assert chal.msg_type == wire.MSG_AUTH
                assert len(chal.body) == wire.AUTH_NONCE_LEN
                if i % 3 == 0:  # skip auth, go straight to a request
                    wire.send_frame(s, wire.MSG_PING, 2,
                                    struct.pack("<Q", 0))
                else:
                    mac = rng.bytes(int(rng.randint(0, 64)))
                    wire.send_frame(s, wire.MSG_AUTH, 2, mac)
                reply = wire.recv_frame(s)
                assert reply.msg_type == wire.MSG_ERR
                code, _ = wire.unpack_err(reply.body)
                assert code == wire.ERR_AUTH_FAILED
                try:
                    assert s.recv(1) == b""  # server hung up
                except ConnectionError:
                    pass
        ok = RemoteDeltaStore([(HOST, cell.port)], r=1, auth_key="k3y",
                              pool_bytes=0)
        with pytest.raises(KeyMissing):
            ok.get(key_for(0))
        ok.close()
    finally:
        cell.stop()
