"""Distributed TAF execution: the shard_map path on 8 placeholder devices
(subprocess so the device count doesn't leak into other tests)."""
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    assert len(jax.devices()) == 8
    from repro.core.tgi import TGI, TGIConfig
    from repro.data.temporal_graph_gen import generate
    from repro.storage.kvstore import DeltaStore
    from repro.taf import analytics, build_sots
    from repro.taf import exec as taf_exec

    events = generate(2500, seed=2)
    cfg = TGIConfig(n_shards=2, parts_per_shard=2, events_per_span=900)
    tgi = TGI.build(events, cfg, DeltaStore(m=2, r=1, backend="mem"))
    t0g, t1g = events.time_range()
    t0, t1 = int(t0g + 0.3 * (t1g - t0g)), int(t0g + 0.8 * (t1g - t0g))
    sots = build_sots(tgi, t0, t1)
    tm = (t0 + t1) // 2
    got = taf_exec.sharded_degree_at(sots, tm)           # 8-way shard_map
    _, want = analytics.degree_series_delta(sots, points=[tm])
    on = sots.init_present == 1
    np.testing.assert_allclose(got[on].astype(float), want[on, 0])
    print("DISTRIBUTED_OK", len(sots))
    """
)


def test_sharded_taf_on_8_devices():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, timeout=540,
    )
    assert "DISTRIBUTED_OK" in out.stdout, out.stderr[-2000:]
