"""Shared pytest plumbing.

``@pytest.mark.timeout(seconds)`` — hard wall-clock bound on a single
test, enforced with SIGALRM (no external plugin).  Socket tests carry
it so a wedged storage cell fails the test instead of hanging CI: the
alarm interrupts any blocking recv/accept in the main thread with a
``TimeoutError``.  On platforms without SIGALRM the marker is a no-op.
"""
import signal

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "timeout(seconds): fail (not hang) if the test runs longer — "
        "SIGALRM-based, main thread only",
    )


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    marker = item.get_closest_marker("timeout")
    if marker is None or not hasattr(signal, "SIGALRM"):
        yield
        return
    seconds = int(marker.args[0]) if marker.args else 60

    def _alarm(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded its {seconds}s timeout marker")

    old = signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(seconds)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)
