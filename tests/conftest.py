"""Shared pytest plumbing.

``@pytest.mark.timeout(seconds)`` — hard wall-clock bound on a single
test, enforced with SIGALRM (no external plugin).  Socket and
concurrency tests carry it so a wedged storage cell or deadlocked
maintenance thread fails the test instead of hanging CI: the alarm
interrupts any blocking recv/accept/join in the main thread with a
``TimeoutError``.  On platforms without SIGALRM the marker is a no-op.

When the alarm fires, two things happen beyond the raise:

* every thread's stack is dumped to stderr (``faulthandler``), so a CI
  log shows WHERE the reader/ingester/compactor threads were stuck —
  a bare TimeoutError from the main thread says nothing about a
  deadlock between the other three;
* worker threads the test spawned (anything alive now that wasn't
  alive before the test body ran) are joined briefly and then
  abandoned with a loud stderr note.  Without this, a timed-out stress
  test leaked its still-running readers into the next test, where they
  kept mutating the (garbage-collected) store and produced unrelated
  downstream failures.
"""
import faulthandler
import signal
import sys
import threading

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "timeout(seconds): fail (not hang) if the test runs longer — "
        "SIGALRM-based, main thread only; dumps all thread stacks and "
        "reaps leaked worker threads on expiry",
    )


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    marker = item.get_closest_marker("timeout")
    if marker is None or not hasattr(signal, "SIGALRM"):
        yield
        return
    seconds = int(marker.args[0]) if marker.args else 60
    before = set(threading.enumerate())

    def _alarm(signum, frame):
        sys.stderr.write(
            f"\n=== {item.nodeid}: {seconds}s timeout — all-thread dump "
            f"===\n")
        faulthandler.dump_traceback(file=sys.stderr, all_threads=True)
        raise TimeoutError(
            f"{item.nodeid} exceeded its {seconds}s timeout marker")

    old = signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(seconds)
    timed_out = False
    try:
        outcome = yield
        exc = outcome.excinfo
        timed_out = exc is not None and issubclass(exc[0], TimeoutError)
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)
        if timed_out:
            _reap_leaked_threads(item, before)


def _reap_leaked_threads(item, before):
    """Join (briefly) then abandon threads the timed-out test spawned.

    Stress tests signal their workers through ``threading.Event``; once
    the test body unwound, nothing sets that event, so a worker blocked
    on a queue or socket would otherwise outlive the test and corrupt
    later ones.  A short join gives cooperative workers a chance to
    notice the unwind; anything still alive after that is daemon (the
    suite's convention) and is reported, not waited for — CI must not
    hang a second time on the cleanup of a hang.
    """
    leaked = [t for t in threading.enumerate()
              if t not in before and t is not threading.current_thread()]
    for t in leaked:
        t.join(timeout=1.0)
    alive = [t for t in leaked if t.is_alive()]
    if alive:
        names = ", ".join(t.name for t in alive)
        sys.stderr.write(
            f"\n=== {item.nodeid}: abandoned {len(alive)} still-running "
            f"worker thread(s) after timeout: {names} ===\n")
