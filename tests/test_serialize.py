"""Storage-format tests: per-encoder round-trips (randomized dtypes and
shapes), the committed TGI1 golden blob (backward compat must stay
byte-identical), projection-skips-decompression, and the storage
accounting that TGI2 threads through kvstore/FetchCost."""
import pathlib

import numpy as np
import pytest

from repro.storage import serialize as S
from repro.storage.kvstore import DeltaKey, DeltaStore

DATA = pathlib.Path(__file__).parent / "data"

DTYPES = [np.bool_, np.int8, np.int16, np.int32, np.int64,
          np.uint8, np.uint16, np.uint32, np.float32, np.float64]


def _random_array(rng, dtype):
    shape_kind = rng.randint(3)
    if shape_kind == 0:
        shape = (rng.randint(0, 400),)
    elif shape_kind == 1:
        shape = (rng.randint(1, 20), rng.randint(1, 20))
    else:
        shape = (rng.randint(1, 6), rng.randint(1, 10), rng.randint(1, 8))
    dt = np.dtype(dtype)
    if dt == np.bool_:
        return rng.rand(*shape) < rng.rand()
    if dt.kind == "f":
        return (rng.randn(*shape) * 10 ** rng.randint(-3, 6)).astype(dt)
    info = np.iinfo(dt)
    lo = max(info.min, -2**48)
    hi = min(info.max, 2**48)
    span = rng.choice([3, 200, hi - lo - 1])  # low-card / narrow / wide
    base = rng.randint(lo, max(lo + 1, hi - int(span)))
    return rng.randint(base, base + int(span) + 1, shape).astype(dt)


@pytest.mark.parametrize("fmt", ["TGI1", "TGI2"])
def test_roundtrip_random_property(fmt):
    rng = np.random.RandomState(11)
    for trial in range(60):
        arrays = {
            f"c{i}": _random_array(rng, DTYPES[rng.randint(len(DTYPES))])
            for i in range(rng.randint(1, 6))
        }
        out = S.loads(S.dumps(arrays, fmt=fmt))
        for k, v in arrays.items():
            assert out[k].dtype == v.dtype, (fmt, trial, k)
            assert out[k].shape == v.shape, (fmt, trial, k)
            assert np.array_equal(out[k], v), (fmt, trial, k)


@pytest.mark.parametrize("profile", ["size", "speed"])
def test_roundtrip_per_encoder(profile):
    """Columns crafted to hit each encoder, verified via block_info."""
    rng = np.random.RandomState(5)
    arrays = {
        "sorted_big": np.sort(rng.randint(0, 10**12, 3000)).astype(np.int64),
        "sorted_smooth": (np.arange(2000, dtype=np.int64) * 3
                          + rng.randint(0, 2, 2000)),
        "bools": rng.rand(7, 311) < 0.4,
        "lowcard": rng.randint(-1, 5, (256, 4)).astype(np.int32),
        "constant": np.full(900, -1, np.int32),
        "bounded": rng.randint(1000, 1200, 1500).astype(np.int32),
        "entropy": rng.randint(-2**40, 2**40, 500).astype(np.int64),
        "unsorted_falls_back": rng.permutation(10**6)[:800].astype(np.int64),
        "floats": rng.randn(400).astype(np.float64),
    }
    blob = S.dumps(arrays, fmt="TGI2", profile=profile)
    out = S.loads(blob)
    for k, v in arrays.items():
        assert np.array_equal(out[k], v) and out[k].dtype == v.dtype, k
    info = S.block_info(blob)
    assert info["bools"]["encoding"] == "bitpack"
    if profile == "size":
        assert info["lowcard"]["encoding"] in ("dict", "zlib")
    else:  # latency-biased: ~10x is required before raw is displaced
        assert info["lowcard"]["encoding"] in ("dict", "zlib", "raw")
    assert info["constant"]["encoding"] in ("dict", "zlib")
    assert info["constant"]["stored_bytes"] < 64  # ~nothing either way
    assert info["sorted_big"]["encoding"] in ("delta_varint", "delta_narrow")
    # unsorted integer columns must fall back cleanly (never delta-coded)
    assert "delta" not in info["unsorted_falls_back"]["encoding"]
    # every stored column is no bigger than raw + its directory entry
    for k, v in arrays.items():
        assert info[k]["stored_bytes"] <= max(v.nbytes, 1) + 32, k


def test_empty_arrays_and_empty_block():
    for fmt in ("TGI1", "TGI2"):
        out = S.loads(S.dumps({}, fmt=fmt))
        assert out == {}
        out = S.loads(S.dumps({"e": np.empty((0, 3), np.float32)}, fmt=fmt))
        assert out["e"].shape == (0, 3) and out["e"].dtype == np.float32


def test_tgi1_golden_blob_byte_identical():
    """The committed TGI1 blob must keep loading, and the TGI1 writer
    must keep producing byte-identical output (old stores stay readable
    AND hash-stable)."""
    blob = (DATA / "tgi1_golden.bin").read_bytes()
    rng = np.random.RandomState(20260728)
    arrays = {
        "t": np.sort(rng.randint(0, 10**6, 512)).astype(np.int64),
        "valid": rng.rand(4, 128) < 0.3,
        "present": (rng.rand(4, 128) < 0.8).astype(np.int8),
        "attrs": rng.randint(-1, 6, (4, 128, 4)).astype(np.int32),
        "e_src": np.sort(rng.randint(0, 512, 300)).astype(np.int32),
        "e_dst": rng.randint(0, 512, 300).astype(np.int32),
        "e_op": rng.randint(0, 2, 300).astype(np.int8),
        "e_val": rng.randint(-1, 4, 300).astype(np.int32),
        "f32": rng.randn(64).astype(np.float32),
        "empty": np.empty((0,), np.int32),
    }
    assert S.dumps(arrays, fmt="TGI1") == blob, "TGI1 writer drifted"
    out = S.loads(blob)
    for k, v in arrays.items():
        assert np.array_equal(out[k], v) and out[k].dtype == v.dtype, k
    # and the same payload survives a TGI2 rewrite
    out2 = S.loads(S.dumps(arrays, fmt="TGI2"))
    for k, v in arrays.items():
        assert np.array_equal(out2[k], v), k


def test_projection_skips_decompression(monkeypatch):
    """fields= must decode ONLY the projected columns: unread columns
    are seeked over via the directory, never decompressed."""
    rng = np.random.RandomState(2)
    arrays = {
        "keep": np.sort(rng.randint(0, 10**9, 2000)).astype(np.int64),
        "skip_a": rng.randint(-1, 5, (300, 4)).astype(np.int32),
        "skip_b": rng.rand(2000) < 0.5,
    }
    blob = S.dumps(arrays, fmt="TGI2")
    decoded = []
    orig = S._decode_column

    def spy(enc, payload, shape, dt):
        decoded.append(enc)
        return orig(enc, payload, shape, dt)

    monkeypatch.setattr(S, "_decode_column", spy)
    out, enc_read, raw_read = S.loads_sized(blob, fields=["keep"])
    assert list(out) == ["keep"]
    assert len(decoded) == 1  # exactly one column decoded
    info = S.block_info(blob)
    assert enc_read == info["keep"]["stored_bytes"] + 8
    assert raw_read == arrays["keep"].nbytes


def test_loads_sized_accounting():
    rng = np.random.RandomState(9)
    arrays = {"a": np.sort(rng.randint(0, 10**7, 4000)).astype(np.int64),
              "b": rng.rand(1000) < 0.2}
    blob = S.dumps(arrays, fmt="TGI2")
    out, enc_read, raw_read = S.loads_sized(blob)
    assert raw_read == sum(v.nbytes for v in arrays.values())
    assert enc_read < raw_read  # compressed
    assert enc_read <= len(blob)


def test_kvstore_tracks_raw_vs_encoded_and_decompressed():
    rng = np.random.RandomState(4)
    store = DeltaStore(m=2, r=1, backend="mem", fmt="TGI2")
    arrays = {"t": np.sort(rng.randint(0, 10**6, 2000)).astype(np.int64),
              "x": rng.randint(-1, 4, (500, 4)).astype(np.int32)}
    key = DeltaKey(0, 0, "S:0:0", 0)
    store.put(key, arrays)
    raw, enc = store.key_sizes[key]
    assert raw == sum(v.nbytes for v in arrays.values())
    assert enc < raw
    assert store.stats.bytes_raw_written == raw
    assert store.stats.bytes_written == enc
    store.stats.reset()
    sizes = {}
    store.get(key, sizes=sizes)
    enc_read, raw_read, pool_read, pool_cols = sizes[key]
    assert raw_read == raw
    assert (pool_read, pool_cols) == (0, 0)  # cold read: nothing pooled
    assert store.stats.bytes_decompressed == raw
    assert store.stats.bytes_read == enc_read <= enc + 16
    # second read: served from the decoded-block pool — zero physical
    # decode, the raw bytes move to the pool bucket
    sizes2 = {}
    store.get(key, sizes=sizes2)
    enc2, raw2, pool2, cols2 = sizes2[key]
    assert (enc2, raw2) == (0, 0)
    assert pool2 == raw and cols2 == len(arrays)
    assert store.stats.bytes_decompressed == raw  # unchanged: no new decode
    assert store.stats.bytes_pool_served == raw


def test_mixed_format_store_reads_both():
    """A TGI2-writing store still reads TGI1 blobs (MAGIC dispatch)."""
    rng = np.random.RandomState(6)
    arrays = {"v": rng.randint(0, 100, 300).astype(np.int32)}
    store = DeltaStore(m=1, r=1, backend="mem", fmt="TGI2")
    old_key = DeltaKey(0, 0, "S:0:0", 0)
    store._mem[0][old_key] = S.dumps(arrays, fmt="TGI1")  # legacy blob
    out = store.get(old_key)
    assert np.array_equal(out["v"], arrays["v"])


def test_varint_codec_extremes():
    for vals in (
        np.array([0], np.uint64),
        np.array([2**63 - 1, 0, 127, 128, 2**40], np.uint64).cumsum(),
        np.arange(1000, dtype=np.uint64) * 127,
    ):
        enc = S._uvarint_encode(vals)
        got = S._uvarint_decode(enc, len(vals))
        assert np.array_equal(got, vals)


def test_storage_report_components():
    from repro.core.tgi import TGI, TGIConfig
    from repro.data.temporal_graph_gen import generate

    events = generate(1500, seed=13)
    cfg = TGIConfig(n_shards=2, parts_per_shard=2, events_per_span=800,
                    eventlist_size=128, checkpoints_per_span=2,
                    replicate_1hop=True)
    store = DeltaStore(m=2, r=1, backend="mem", fmt="TGI2")
    tgi = TGI.build(events, cfg, store)
    rep = tgi.storage_report()
    assert rep["format"] == "TGI2"
    assert {"eventlists", "hierarchy"} <= set(rep["components"])
    assert "aux_replicas" in rep["components"]  # replicate_1hop=True
    tot = rep["totals"]
    assert tot["raw"] == sum(c["raw"] for c in rep["components"].values())
    assert tot["encoded"] == sum(c["encoded"] for c in rep["components"].values())
    assert 0 < tot["ratio"] < 1  # TGI2 compresses this workload
    # accounting matches the store's own write counters (r=1)
    assert tot["encoded"] == store.stats.bytes_written
    assert tot["raw"] == store.stats.bytes_raw_written


def test_fetchcost_has_decompression_dimension():
    from repro.core.tgi import TGI, TGIConfig
    from repro.data.temporal_graph_gen import generate

    events = generate(1500, seed=13)
    cfg = TGIConfig(n_shards=2, parts_per_shard=2, events_per_span=800,
                    eventlist_size=128, checkpoints_per_span=2)
    store = DeltaStore(m=2, r=1, backend="mem", fmt="TGI2")
    tgi = TGI.build(events, cfg, store)
    t = int(np.mean(events.time_range()))
    tgi.get_snapshot(t)
    cost = tgi.last_cost
    assert cost.n_bytes_decompressed > cost.n_bytes > 0
    # snapshot-LRU hits replay the same logical cost, both dimensions
    snap_cost = (cost.n_deltas, cost.n_bytes, cost.n_bytes_decompressed)
    tgi.get_snapshot(t)
    c2 = tgi.last_cost
    assert (c2.n_deltas, c2.n_bytes, c2.n_bytes_decompressed) == snap_cost
