"""TAF operator tests: operator semantics vs naive recomputation, and the
paper's central incremental-computation equivalence (NodeComputeDelta ==
NodeComputeTemporal, Fig. 17) on real TGI-fetched operands."""
import numpy as np
import pytest

from repro.core.tgi import TGI, TGIConfig
from repro.data.temporal_graph_gen import generate, naive_state_at
from repro.storage.kvstore import DeltaStore
from repro.taf import analytics, operators as ops
from repro.taf.son import build_son, build_sots


@pytest.fixture(scope="module")
def setup():
    events = generate(4000, seed=13)
    cfg = TGIConfig(n_shards=2, parts_per_shard=2, events_per_span=1200,
                    eventlist_size=128, checkpoints_per_span=3)
    tgi = TGI.build(events, cfg, DeltaStore(m=3, r=1, backend="mem"))
    t0g, t1g = events.time_range()
    t0 = int(t0g + 0.3 * (t1g - t0g))
    t1 = int(t0g + 0.8 * (t1g - t0g))
    sots = build_sots(tgi, t0, t1)
    return events, cfg, tgi, sots, t0, t1


def test_son_initial_state_matches_naive(setup):
    events, cfg, tgi, sots, t0, t1 = setup
    want = naive_state_at(events, t0, cfg.n_attrs)
    want.grow(int(sots.node_ids.max()) + 1)
    assert (sots.init_present == want.present[sots.node_ids]).all()
    assert (sots.init_attrs == want.attrs[sots.node_ids]).all()


def test_timeslice_matches_naive(setup):
    events, cfg, tgi, sots, t0, t1 = setup
    tm = (t0 + t1) // 2
    sl = ops.timeslice(sots, tm)
    want = naive_state_at(events, tm, cfg.n_attrs)
    want.grow(int(sots.node_ids.max()) + 1)
    assert (sl["present"] == want.present[sots.node_ids]).all()
    on = sl["present"] == 1
    assert (sl["attrs"][on] == want.attrs[sots.node_ids][on]).all()


def test_selection(setup):
    events, cfg, tgi, sots, t0, t1 = setup
    sub = ops.selection(sots, lambda s: s.init_present == 1)
    assert (sub.init_present == 1).all()
    assert len(sub) == int((sots.init_present == 1).sum())


def test_graph_operator_edges_match_naive(setup):
    events, cfg, tgi, sots, t0, t1 = setup
    tm = (t0 + t1) // 2
    g = ops.graph(sots, tm)
    want = naive_state_at(events, tm, cfg.n_attrs)
    want.grow(len(g.present))
    # graph() keeps only edges with both endpoints in the SoTS: here the
    # SoTS is the full node set at t0 + touched nodes, so edge sets over
    # common present nodes must match
    member = set(sots.node_ids.tolist())
    src, dst, _ = want.edges()
    keep = np.array([u in member and v in member for u, v in zip(src, dst)])
    from repro.core.snapshot import pack_edge_key

    want_keys = np.sort(pack_edge_key(
        np.minimum(src[keep], dst[keep]), np.maximum(src[keep], dst[keep])
    ))
    assert (np.sort(g.edge_key) == want_keys).all()


def test_delta_equals_temporal_degree(setup):
    """The Fig.-17 pair on degree: incremental == per-version recompute."""
    events, cfg, tgi, sots, t0, t1 = setup
    pts = sots.change_points()[::5][:20]
    ts_a, a = analytics.degree_series_temporal(sots, pts)
    ts_b, b = analytics.degree_series_delta(sots, pts)
    assert (ts_a == ts_b).all()
    # compare only nodes present at t0 (absent nodes define degree 0 in
    # the temporal path and init-adjacency degree in the delta path)
    on = sots.init_present == 1
    np.testing.assert_allclose(a[on], b[on])


def test_delta_equals_temporal_label_count(setup):
    events, cfg, tgi, sots, t0, t1 = setup
    pts = sots.change_points()[::7][:12]
    label = int(np.bincount(sots.init_attrs[:, 0][sots.init_attrs[:, 0] >= 0]).argmax())
    ts_a, a = analytics.label_count_temporal(sots, label, points=pts)
    ts_b, b = analytics.label_count_delta(sots, label, points=pts)
    on = sots.init_present == 1
    np.testing.assert_allclose(a[on], b[on])


def test_compare_operator(setup):
    events, cfg, tgi, sots, t0, t1 = setup

    def f(present, attrs, son, i, t):
        return float(present)

    ids, diff = ops.compare(sots, sots, f)
    assert (diff == 0).all()
    nids, d2 = ops.compare_timeslices(sots, f, t0, (t0 + t1) // 2)
    assert set(np.unique(d2)).issubset({-1.0, 0.0, 1.0})


def test_evolution_and_aggregation(setup):
    events, cfg, tgi, sots, t0, t1 = setup
    pts, dens = analytics.density_evolution(sots, n_samples=6)
    assert len(dens) == 6 and (dens >= 0).all() and (dens <= 1).all()
    assert ops.temp_aggregate(dens, "max") >= ops.temp_aggregate(dens, "mean")
    peaks = ops.temp_aggregate(np.array([0, 1, 0, 2, 0]), "peak")
    assert list(peaks) == [1, 3]
    sat = ops.temp_aggregate(np.array([0.0, 0.5, 0.96, 1.0]), "saturate")
    assert sat == 2


def test_max_lcc_matches_bruteforce(setup):
    events, cfg, tgi, sots, t0, t1 = setup
    tm = (t0 + t1) // 2
    nid, v = analytics.max_lcc(sots, tm)
    g = ops.graph(sots, tm)
    lcc = analytics.local_clustering(g)
    assert v == max(lcc.values())


def test_pagerank_warm_start_converges_faster(setup):
    events, cfg, tgi, sots, t0, t1 = setup
    pts = np.linspace(t0, t1, 5).astype(np.int64)
    ranks_w, iters_w = analytics.pagerank_over_time(sots, pts, warm_start=True)
    ranks_c, iters_c = analytics.pagerank_over_time(sots, pts, warm_start=False)
    # same fixed point
    for rw, rc in zip(ranks_w, ranks_c):
        common = set(rw) & set(rc)
        for v in common:
            assert abs(rw[v] - rc[v]) < 1e-6
    assert sum(iters_w[1:]) <= sum(iters_c[1:])


def test_sharded_degree_matches_host(setup):
    events, cfg, tgi, sots, t0, t1 = setup
    from repro.taf import exec as taf_exec

    tm = (t0 + t1) // 2
    got = taf_exec.sharded_degree_at(sots, tm)
    pts, want = analytics.degree_series_delta(sots, points=[tm])
    on = sots.init_present == 1
    np.testing.assert_allclose(got[on].astype(float), want[on, 0])
