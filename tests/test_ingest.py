"""Ingest subsystem: build/update/append parity through the shared
SpanBuilder, incremental version chains, streaming open-span reads,
span compaction with store GC, and scoped cache invalidation."""
import numpy as np
import pytest

from repro.core import ingest as ingest_mod
from repro.core.events import EventLog
from repro.core.slots import SlotMap, hash32
from repro.core.snapshot import GraphState
from repro.core.tgi import TGI, TGIConfig
from repro.core.version_chain import VersionChains
from repro.data.temporal_graph_gen import generate, naive_state_at
from repro.storage.kvstore import DeltaKey, DeltaStore

N_EVENTS = 4000
CFG = dict(n_shards=2, parts_per_shard=2, events_per_span=1000,
           eventlist_size=100, checkpoints_per_span=2)


def _states_equal(a: GraphState, b: GraphState, msg=""):
    n = max(len(a.present), len(b.present))
    a.grow(n)
    b.grow(n)
    assert (a.present == b.present).all(), f"presence mismatch {msg}"
    on = a.present == 1
    assert (a.attrs[on] == b.attrs[on]).all(), f"attr mismatch {msg}"
    assert len(a.edge_key) == len(b.edge_key), f"edge count {msg}"
    assert (a.edge_key == b.edge_key).all(), f"edge keys {msg}"
    assert (a.edge_val == b.edge_val).all(), f"edge attrs {msg}"


def _histories_equal(tgi_a: TGI, tgi_b: TGI, nids, t0: int, t1: int, msg=""):
    for nid in nids:
        ia, ea = tgi_a.get_node_history(int(nid), t0, t1)
        ib, eb = tgi_b.get_node_history(int(nid), t0, t1)
        assert (ia is None) == (ib is None), f"init presence {nid} {msg}"
        if ia is not None:
            assert (ia["attrs"] == ib["attrs"]).all(), f"init attrs {nid} {msg}"
            assert set(ia["neighbors"].tolist()) == set(ib["neighbors"].tolist())
        assert len(ea) == len(eb), f"event count {nid} {msg}"
        for col in ("t", "kind", "src", "dst", "key", "val"):
            assert (getattr(ea, col) == getattr(eb, col)).all(), f"{col} {nid} {msg}"


def _chains_equal(tgi_a: TGI, tgi_b: TGI, nids, t0=None, t1=None, msg=""):
    """Version-chain parity: reference times match; (tsid, bucket) may
    differ across layouts but must resolve to the same history (checked
    via _histories_equal)."""
    for nid in nids:
        ta = tgi_a.vc.get(int(nid), t0, t1)[0]
        tb = tgi_b.vc.get(int(nid), t0, t1)[0]
        assert len(ta) == len(tb) and (ta == tb).all(), f"vc times {nid} {msg}"
        assert tgi_a.vc.n_versions(int(nid)) == tgi_b.vc.n_versions(int(nid))


@pytest.fixture(scope="module")
def history():
    events = generate(N_EVENTS, seed=17)
    cfg = TGIConfig(**CFG)
    bulk = TGI.build(events, cfg, DeltaStore(m=2, r=1, backend="mem"))
    return events, cfg, bulk


def _probe(events, bulk, other, msg):
    t0, t1 = events.time_range()
    ts = [int(t0 + f * (t1 - t0)) for f in (0.1, 0.33, 0.61, 0.95)]
    for t in ts:
        _states_equal(bulk.get_snapshot(t), other.get_snapshot(t),
                      f"{msg} t={t}")
    hub_state = naive_state_at(events, ts[-1], bulk.cfg.n_attrs)
    nids = np.argsort(-hub_state.degree())[:4]
    _histories_equal(bulk, other, nids, ts[0], ts[-1], msg)
    _chains_equal(bulk, other, nids, msg=msg)


# ---------------------------------------------------------------------------
# Parity: build(all) == build(prefix)+update(suffix) == chained appends,
# before and after compact()
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("splits", [(2000,), (500, 900, 1400, 2600, 3300)])
def test_update_parity_with_bulk_build(history, splits):
    events, cfg, bulk = history
    cuts = (0,) + splits + (len(events),)
    inc = TGI.build(events.take(slice(0, cuts[1])), cfg,
                    DeltaStore(m=2, r=1, backend="mem"))
    for lo, hi in zip(cuts[1:], cuts[2:]):
        inc.update(events.take(slice(lo, hi)))
    _probe(events, bulk, inc, f"update {splits}")
    stats = inc.compact()
    assert stats.spans_after <= stats.spans_before
    _probe(events, bulk, inc, f"update+compact {splits}")


def test_streamed_append_parity(history):
    events, cfg, bulk = history
    st = TGI.build(events.take(slice(0, 700)), cfg,
                   DeltaStore(m=2, r=1, backend="mem"))
    rng = np.random.RandomState(0)
    lo = 700
    while lo < len(events):
        hi = min(lo + int(rng.randint(50, 400)), len(events))
        st.append(events.take(slice(lo, hi)))
        lo = hi
    st.flush()
    assert len(st._pending) == 0
    _probe(events, bulk, st, "append")
    stats = st.compact()
    assert stats.spans_after <= stats.spans_before
    _probe(events, bulk, st, "append+compact")


def test_open_span_reads_mid_stream(history):
    """Queries against a partially-ingested index are served correctly:
    sealed spans off storage, the open span from the buffer's live state."""
    events, cfg, bulk = history
    st = TGI.build(events.take(slice(0, 1000)), cfg,
                   DeltaStore(m=2, r=1, backend="mem"))
    for lo in range(1000, 3400, 300):
        hi = min(lo + 300, len(events))
        st.append(events.take(slice(lo, hi)))
        prefix = events.take(slice(0, hi))
        t_head = int(prefix.t[-1])
        t_mid = int((st._events.t[-1] + t_head) // 2)
        for t in (t_head, t_mid):
            _states_equal(st.get_snapshot(t),
                          naive_state_at(prefix, t, cfg.n_attrs),
                          f"open read t={t} lo={lo}")
    # node histories crossing the sealed/buffered boundary
    assert len(st._pending), "test should probe a partially-sealed index"
    t0g = int(events.t[0])
    t1g = int(st.time_range()[1])
    prefix = events.take(slice(0, 3400))
    deg = naive_state_at(prefix, t1g, cfg.n_attrs).degree()
    for nid in np.argsort(-deg)[:3]:
        init, ev = st.get_node_history(int(nid), t0g, t1g)
        sel = (((prefix.src == nid) | (prefix.dst == nid))
               & (prefix.t > t0g) & (prefix.t <= t1g))
        want = prefix.take(np.nonzero(sel)[0])
        assert len(ev) == len(want)
        assert (ev.t == want.t).all() and (ev.kind == want.kind).all()


def test_append_new_node_only_in_buffer():
    """A node that exists only in unsealed events is still visible to
    snapshots and histories (no sealed SlotMap knows it yet)."""
    ev = EventLog.from_arrays(
        t=[1, 2, 3], kind=[0, 0, 2], src=[0, 1, 0], dst=[-1, -1, 1])
    cfg = TGIConfig(n_shards=2, parts_per_shard=1, events_per_span=100,
                    eventlist_size=4, checkpoints_per_span=1)
    tgi = TGI.build(ev, cfg, DeltaStore(m=2, r=1, backend="mem"))
    fresh = EventLog.from_arrays(t=[10, 11], kind=[0, 2], src=[7, 7], dst=[-1, 0])
    tgi.append(fresh)  # below the span threshold: stays buffered
    assert len(tgi._pending) == 2
    g = tgi.get_snapshot(11)
    assert g.present[7] == 1
    init, hist = tgi.get_node_history(7, 10, 11)
    assert init is not None  # present at t0=10, edge not yet (t=11)
    assert len(init["neighbors"]) == 0
    assert len(hist) == 1  # the edge event in (10, 11]
    init2, _ = tgi.get_node_history(7, 11, 12)
    assert init2 is not None and 0 in init2["neighbors"]


# ---------------------------------------------------------------------------
# Satellite: update respects locality partitioning (regression)
# ---------------------------------------------------------------------------


def test_update_spans_use_locality_partitioning():
    events = generate(2500, seed=11)
    cfg = TGIConfig(n_shards=2, parts_per_shard=2, events_per_span=900,
                    eventlist_size=100, checkpoints_per_span=2,
                    partition_strategy="locality")
    tgi = TGI.build(events.take(slice(0, 900)), cfg,
                    DeltaStore(m=2, r=1, backend="mem"))
    tgi.update(events.take(slice(900, 2500)))
    builder = ingest_mod.SpanBuilder(cfg, DeltaStore(m=2, r=1, backend="mem"))
    assert len(tgi.spans) >= 2, "need at least one update-built span"
    saw_non_hash = False
    for si in tgi.spans[1:]:
        sp = si.span
        ev_span = events.take(slice(sp.ev_lo, sp.ev_hi))
        state = tgi.get_snapshot(tgi.spans[
            tgi.spans.index(si) - 1].span.t_end)
        want = builder.partition_span(sp.tsid, ev_span, state)
        assert (si.smap.node_ids == want.node_ids).all()
        assert (si.smap.pid == want.pid).all(), (
            "update-built span does not use the shared locality partitioner")
        hash_pid = (hash32(si.smap.node_ids)
                    % np.uint32(cfg.n_parts)).astype(np.int32)
        saw_non_hash |= bool((si.smap.pid != hash_pid).any())
    assert saw_non_hash, "locality layout degenerated to pure hash"
    # and the index still answers correctly
    t0, t1 = events.time_range()
    t = int(t0 + 0.8 * (t1 - t0))
    _states_equal(tgi.get_snapshot(t), naive_state_at(events, t, cfg.n_attrs))


def test_update_spans_store_aux_replicas_when_configured():
    """replicate_1hop was silently dropped by the old update path."""
    events = generate(2000, seed=11)
    cfg = TGIConfig(n_shards=2, parts_per_shard=2, events_per_span=700,
                    eventlist_size=100, checkpoints_per_span=2,
                    partition_strategy="locality", replicate_1hop=True)
    store = DeltaStore(m=2, r=1, backend="mem")
    tgi = TGI.build(events.take(slice(0, 700)), cfg, store)
    tgi.update(events.take(slice(700, 2000)))
    update_tsids = {si.span.tsid for si in tgi.spans[1:]}
    aux_tsids = {k.tsid for k in store.key_sizes if k.did.startswith("X:")}
    assert update_tsids & aux_tsids, "update-built spans lack aux replicas"


# ---------------------------------------------------------------------------
# Incremental version chains
# ---------------------------------------------------------------------------


def test_version_chain_append_matches_bulk_build():
    events = generate(3000, seed=23)
    n = events.n_nodes
    span_of = (np.arange(len(events)) // 500).astype(np.int32)
    bucket_of = ((np.arange(len(events)) % 500) // 100).astype(np.int32)
    bulk = VersionChains.build(events, span_of, bucket_of, n)
    inc = VersionChains.build(events.take(slice(0, 1000)), span_of[:1000],
                              bucket_of[:1000], events.take(slice(0, 1000)).n_nodes)
    for lo in range(1000, 3000, 400):
        hi = min(lo + 400, 3000)
        ev = events.take(slice(lo, hi))
        inc.append(ev, span_of[lo:hi], bucket_of[lo:hi], n)
    assert inc.segments, "appends should create CSR segments"
    for nid in range(0, n, 7):
        a = bulk.get(nid)
        b = inc.get(nid)
        for x, y in zip(a, b):
            assert (x == y).all(), f"nid={nid}"
        assert bulk.n_versions(nid) == inc.n_versions(nid)
    inc.consolidate()
    assert not inc.segments
    for nid in range(0, n, 7):
        a, b = bulk.get(nid), inc.get(nid)
        assert all((x == y).all() for x, y in zip(a, b)), f"nid={nid}"
    assert (bulk.indptr == inc.indptr).all()
    assert (bulk.t == inc.t).all()
    assert (bulk.tsid == inc.tsid).all()
    assert (bulk.bucket == inc.bucket).all()


def test_version_chain_auto_consolidates():
    ev = EventLog.from_arrays(t=[0], kind=[0], src=[0], dst=[-1])
    vc = VersionChains.build(ev, np.zeros(1, np.int32), np.zeros(1, np.int32), 1)
    for i in range(VersionChains.AUTO_CONSOLIDATE + 1):
        e = EventLog.from_arrays(t=[i + 1], kind=[0], src=[0], dst=[-1])
        vc.append(e, np.zeros(1, np.int32), np.zeros(1, np.int32), 1)
    assert len(vc.segments) <= VersionChains.AUTO_CONSOLIDATE
    t, _, _ = vc.get(0)
    assert (t == np.arange(VersionChains.AUTO_CONSOLIDATE + 2)).all()


# ---------------------------------------------------------------------------
# Compaction + store GC
# ---------------------------------------------------------------------------


def _micro_span_index(events, cfg, store, batch=100, head=500):
    tgi = TGI.build(events.take(slice(0, head)), cfg, store)
    for lo in range(head, len(events), batch):
        tgi.update(events.take(slice(lo, min(lo + batch, len(events)))))
    return tgi


def test_compact_merges_micro_spans_and_gcs_store(history):
    events, cfg, bulk = history
    store = DeltaStore(m=2, r=1, backend="mem")
    tgi = _micro_span_index(events, cfg, store)
    before = tgi.storage_report()["totals"]
    live_before = tgi.index_size_bytes()
    n_spans = len(tgi.spans)
    stats = tgi.compact()
    assert stats.spans_before == n_spans
    assert stats.spans_after * 4 <= stats.spans_before, (
        "micro-span-heavy workload should compact >= 4x")
    assert stats.keys_deleted > 0 and store.stats.n_deletes == stats.keys_deleted
    after = tgi.storage_report()["totals"]
    assert after["encoded"] < before["encoded"], "size_report must shrink"
    assert after["count"] < before["count"]
    assert tgi.index_size_bytes() < live_before
    # accounting stays self-consistent: live bytes == report bytes (r=1)
    assert tgi.index_size_bytes() == after["encoded"]
    assert (store.stats.bytes_written - store.stats.bytes_deleted
            == after["encoded"])
    _probe(events, bulk, tgi, "compacted")
    # idempotent: a second pass finds nothing to merge
    again = tgi.compact()
    assert again.runs_merged == 0 and again.spans_after == stats.spans_after


def test_compact_file_backend_tombstones(tmp_path):
    events = generate(1500, seed=29)
    cfg = TGIConfig(n_shards=2, parts_per_shard=2, events_per_span=600,
                    eventlist_size=64, checkpoints_per_span=2)
    store = DeltaStore(m=3, r=2, backend="file", root=str(tmp_path))
    tgi = _micro_span_index(events, cfg, store, batch=80, head=300)
    old_tsids = [s.span.tsid for s in tgi.spans]
    stats = tgi.compact()
    assert stats.keys_deleted > 0
    # tombstoned keys are gone from reads and from placement listings
    for tsid in old_tsids:
        if tsid in {s.span.tsid for s in tgi.spans}:
            continue
        for sid in range(cfg.n_shards):
            assert store.keys_for_placement(tsid, sid) == []
    t0, t1 = events.time_range()
    t = int(t0 + 0.7 * (t1 - t0))
    _states_equal(tgi.get_snapshot(t), naive_state_at(events, t, cfg.n_attrs))


def test_delta_store_delete_roundtrip():
    store = DeltaStore(m=2, r=2, backend="mem")
    key = DeltaKey(0, 0, "S:0:0", 0)
    store.put(key, {"x": np.arange(100, dtype=np.int32)})
    assert store.key_sizes[key]
    assert store.delete(key)
    assert key not in store.key_sizes
    assert store.stats.n_deletes == 1
    assert store.stats.bytes_deleted > 0
    assert store.live_bytes() == 0
    with pytest.raises(KeyError):
        store.get(key)
    assert not store.delete(key)  # double delete is a no-op


# ---------------------------------------------------------------------------
# Scoped cache invalidation
# ---------------------------------------------------------------------------


def test_update_invalidation_is_scoped(history):
    events, cfg, _ = history
    store = DeltaStore(m=2, r=1, backend="mem")
    tgi = TGI.build(events.take(slice(0, 3000)), cfg, store)
    t_old = int(events.t[1000])
    tgi.get_snapshot(t_old)  # warm the LRU
    reads0 = store.stats.reads
    tgi.update(events.take(slice(3000, 4000)))
    # snapshot strictly before the new events: still served from cache
    tgi.get_snapshot(t_old)
    assert store.stats.reads == reads0, "old-t snapshot should stay cached"
    # snapshot at/after the new events' start: re-read from storage
    t_new = int(events.t[3500])
    tgi.get_snapshot(t_new)
    assert store.stats.reads > reads0


def test_compact_invalidation_scoped_to_affected_spans(history):
    events, cfg, _ = history
    store = DeltaStore(m=2, r=1, backend="mem")
    tgi = TGI.build(events.take(slice(0, 2000)), cfg, store)
    # accrete micro-spans after a stable full-size prefix
    for lo in range(2000, 4000, 100):
        tgi.update(events.take(slice(lo, lo + 100)))
    t_prefix = int(events.t[500])  # inside the untouched full-size spans
    tgi.get_snapshot(t_prefix)
    stats = tgi.compact()  # issues its own reads to seed the merged run
    assert stats.runs_merged > 0
    reads0 = store.stats.reads
    tgi.get_snapshot(t_prefix)
    assert store.stats.reads == reads0, (
        "compaction must not evict snapshots of untouched spans")
    # a snapshot inside the rewritten range was dropped: storage re-read
    t_merged = int(events.t[2500])
    tgi.get_snapshot(t_merged)
    reads1 = store.stats.reads
    assert reads1 > reads0
    tgi.get_snapshot(t_merged)  # now cached against the new layout
    assert store.stats.reads == reads1


# ---------------------------------------------------------------------------
# Shared-builder internals
# ---------------------------------------------------------------------------


def test_span_bucket_arrays_matches_python_loop(history):
    events, cfg, bulk = history
    span_of, bucket_of = ingest_mod.span_bucket_arrays(bulk.spans)
    assert len(span_of) == len(events) == len(bucket_of)
    out_t, out_b = [], []
    for s in bulk.spans:
        for b, (lo, hi) in enumerate(s.bucket_bounds):
            out_t.extend([s.span.tsid] * (hi - lo))
            out_b.extend([b] * (hi - lo))
    assert (span_of == np.asarray(out_t, np.int32)).all()
    assert (bucket_of == np.asarray(out_b, np.int32)).all()
    assert (bulk._bucket_of_old(bulk.spans) == bucket_of).all()  # shim


def test_time_based_span_sealing():
    n = 600
    ev = EventLog.from_arrays(
        t=np.arange(n) * 10, kind=np.zeros(n, np.int8) + 4,
        src=np.arange(n) % 5, key=np.zeros(n), val=np.arange(n))
    # register the nodes first
    head = EventLog.from_arrays(t=[-1] * 5, kind=[0] * 5, src=list(range(5)))
    cfg = TGIConfig(n_shards=2, parts_per_shard=1, events_per_span=10_000,
                    eventlist_size=64, checkpoints_per_span=2,
                    span_seal_time=1000)
    tgi = TGI.build(head, cfg, DeltaStore(m=2, r=1, backend="mem"))
    tgi.append(ev)
    # event-count threshold (10k) never fires; the time window (1000 time
    # units over a 6000-unit stream) must have sealed spans
    assert len(tgi.spans) > 3
    assert len(tgi._pending) < n
    tgi.flush()
    g = tgi.get_snapshot(int(ev.t[-1]))
    assert (g.attrs[np.arange(5), 0] >= 0).any()
