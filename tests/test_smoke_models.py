"""Per-architecture smoke tests: reduced config, one forward / prefill /
decode step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import lm
from repro.models.common import Init, padded_vocab
from repro.models.sharding import Sharder, split_tree

B, S = 2, 32


def _batch(cfg, rng):
    n_txt = S - (cfg.n_img_tokens or 0)
    batch = {
        "tokens": jax.random.randint(rng, (B, n_txt), 0, cfg.vocab_size, dtype=jnp.int32),
        "labels": jax.random.randint(rng, (B, n_txt), 0, cfg.vocab_size, dtype=jnp.int32),
    }
    if cfg.n_img_tokens:
        batch["img_embeds"] = jax.random.normal(rng, (B, cfg.n_img_tokens, cfg.d_model)) * 0.02
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(rng, (B, cfg.enc_seq, cfg.d_model)) * 0.02
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    rng = jax.random.PRNGKey(0)
    params_pl = lm.init(rng, cfg, max_seq=4 * S)
    params, _ = split_tree(params_pl)
    return cfg, params, _batch(cfg, jax.random.PRNGKey(1))


def test_forward_shapes_finite(arch_setup):
    cfg, params, batch = arch_setup
    shd = Sharder(mesh=None)
    logits, aux = jax.jit(
        lambda p, b: lm.forward(p, b, cfg, shd)
    )(params, batch)
    assert logits.shape == (B, S, padded_vocab(cfg.vocab_size))
    assert bool(jnp.isfinite(logits).all()), f"{cfg.name}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{cfg.name}: non-finite aux"


def test_loss_and_grad_finite(arch_setup):
    cfg, params, batch = arch_setup
    shd = Sharder(mesh=None)
    n_img = cfg.n_img_tokens or 0

    def loss_fn(p):
        logits, aux = lm.forward(p, batch, cfg, shd)
        return lm.lm_loss(logits[:, n_img:], batch["labels"]) + 0.01 * aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss)), cfg.name
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{cfg.name}: NaN grads"


def test_prefill_then_decode(arch_setup):
    cfg, params, batch = arch_setup
    shd = Sharder(mesh=None)
    logits, cache = jax.jit(
        lambda p, b: lm.prefill(p, b, cfg, shd, model_axis=1, cache_len=2 * S)
    )(params, batch)
    assert logits.shape == (B, 1, padded_vocab(cfg.vocab_size))
    assert bool(jnp.isfinite(logits).all()), cfg.name

    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    pos = jnp.full((B,), S, jnp.int32)
    logits2, cache2 = jax.jit(
        lambda p, c, t, q: lm.decode_step(p, c, t, q, cfg, shd)
    )(params, cache, tok, pos)
    assert logits2.shape == (B, 1, padded_vocab(cfg.vocab_size))
    assert bool(jnp.isfinite(logits2).all()), cfg.name
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


def test_decode_matches_forward_full_attn():
    """For a full-attention arch, prefill(S)+decode(t) logits must equal the
    forward pass logits at position t (teacher forcing equivalence)."""
    cfg = get_config("qwen3-1.7b").reduced()
    rng = jax.random.PRNGKey(0)
    params, _ = split_tree(lm.init(rng, cfg, max_seq=4 * S))
    shd = Sharder(mesh=None)
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size, dtype=jnp.int32)

    logits_full, _ = lm.forward(params, {"tokens": toks}, cfg, shd)

    # prefill on the first S-1 tokens, then decode token S-1
    pre_logits, cache = lm.prefill(
        params, {"tokens": toks[:, : S - 1]}, cfg, shd, model_axis=1, cache_len=S
    )
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, 0]), np.asarray(logits_full[:, S - 2]), rtol=2e-2, atol=2e-2
    )
    dec_logits, _ = lm.decode_step(
        params, cache, toks[:, S - 1 :], jnp.full((B,), S - 1, jnp.int32), cfg, shd
    )
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0]), np.asarray(logits_full[:, S - 1]), rtol=2e-2, atol=2e-2
    )
