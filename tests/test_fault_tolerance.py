"""Fault-tolerance: checkpoint round-trips (exact), delta-chain restore,
restore-under-failure, elastic re-mesh policy, deterministic pipeline
seek, EF gradient compression, and end-to-end crash/resume equivalence."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.pipeline import PipelineConfig, SyntheticLM
from repro.launch.elastic import Coordinator, pipeline_seek
from repro.storage.checkpoint import CheckpointConfig, CheckpointStore
from repro.storage.kvstore import DeltaStore


def _tree(seed, scale=1.0):
    rng = np.random.RandomState(seed)
    return {
        "w": rng.randn(300, 170).astype(np.float32) * scale,
        "b": {"x": rng.randn(1000).astype(np.float32),
              "s": np.asarray(seed, np.int32)},
    }


def _trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_checkpoint_roundtrip_exact():
    store = CheckpointStore(DeltaStore(m=4, r=2, backend="mem"),
                            CheckpointConfig(snapshot_every=3))
    trees = []
    for s in range(7):
        t = _tree(s)
        trees.append(t)
        store.save(s, t)
    for s in range(7):
        got, step = store.restore(step=s)
        assert step == s
        _trees_equal(got, trees[s])


def test_checkpoint_delta_chain_smaller_than_full():
    """Delta saves of slowly-changing params compress far below full
    snapshots — the Log-vs-Copy storage win the paper quantifies."""
    base = _tree(0)
    store = CheckpointStore(DeltaStore(m=2, r=1, backend="mem"),
                            CheckpointConfig(snapshot_every=100))
    store.save(0, base)
    b0 = store.store.stats.bytes_written
    drift = jax.tree.map(
        lambda x: x + (np.random.RandomState(1).randn(*x.shape) * 1e-3
                       ).astype(x.dtype) if x.dtype == np.float32 else x, base)
    store.save(1, drift)
    b1 = store.store.stats.bytes_written - b0
    assert b1 < 0.8 * b0, (b1, b0)  # XOR+zlib of a small drift is compact
    got, _ = store.restore(step=1)
    _trees_equal(got, drift)


def test_checkpoint_restore_with_node_failure():
    ds = DeltaStore(m=4, r=2, backend="mem")
    store = CheckpointStore(ds, CheckpointConfig(snapshot_every=2))
    trees = [_tree(s) for s in range(4)]
    for s, t in enumerate(trees):
        store.save(s, t)
    ds.fail_node(1)
    got, step = store.restore()
    assert step == 3
    _trees_equal(got, trees[3])
    assert ds.stats.failovers > 0


def test_async_save_matches_sync():
    store = CheckpointStore(DeltaStore(m=2, r=1, backend="mem"))
    t = _tree(5)
    fut = store.save_async(0, t)
    fut.result()
    got, _ = store.restore()
    _trees_equal(got, t)


def test_elastic_coordinator_failure_and_straggler():
    clock = [0.0]
    co = Coordinator(n_hosts=8, chips_per_host=4, heartbeat_timeout=10,
                     straggler_factor=2.0, clock=lambda: clock[0])
    for step in range(20):
        clock[0] += 1.0
        for h in range(8):
            if h == 3 and step > 5:
                continue  # host 3 dies at step 5
            dt = 1.0 if h != 5 else 3.5  # host 5 straggles
            co.heartbeat(h, dt)
    clock[0] += 20.0  # let host 3 time out
    for h in range(8):
        if h not in (3,):
            co.heartbeat(h)
    plan = co.plan(data_axis=8, model_axis=4)
    assert plan is not None
    assert 3 in plan["dead"]
    assert 5 in plan["quarantined"]
    d2, m2 = plan["mesh"]
    assert m2 == 4 and d2 <= 8 and d2 * m2 <= len(plan["hosts"]) * 4 + 4 * 4
    seek = pipeline_seek(step=120, global_batch=64, n_shards=d2)
    assert seek["step"] == 120 and len(seek["shard_seeds"]) == d2


def test_pipeline_determinism_across_shardings():
    """Global batch content is invariant to the shard count — the property
    elastic re-meshing depends on."""
    a = SyntheticLM(PipelineConfig(16, 32, 1000, n_shards=1), seed=3).batch(7)
    b = SyntheticLM(PipelineConfig(16, 32, 1000, n_shards=4), seed=3).batch(7)
    # per-shard seeding means different layout but the same determinism
    # guarantee per (step, shard); shard 0 of both runs must agree:
    a0 = SyntheticLM(PipelineConfig(16, 32, 1000, n_shards=4), seed=3).shard_batch(7, 0)
    b0 = SyntheticLM(PipelineConfig(16, 32, 1000, n_shards=4), seed=3).shard_batch(7, 0)
    np.testing.assert_array_equal(a0["tokens"], b0["tokens"])
    assert a["tokens"].shape == b["tokens"].shape


def test_ef_compression_reduces_error_over_steps():
    """Error feedback: quantization error is carried, so the *sum* of
    compressed grads tracks the sum of true grads (bias -> 0)."""
    from repro.optim.compression import _dequantize, _quantize

    rng = np.random.RandomState(0)
    err = np.zeros(4096, np.float32)
    true_sum = np.zeros(4096, np.float64)
    comp_sum = np.zeros(4096, np.float64)
    for step in range(50):
        g = rng.randn(4096).astype(np.float32) * (1 + step % 3)
        true_sum += g
        q, scale = _quantize(jnp.asarray(g + err))
        deq = np.asarray(_dequantize(q, scale))
        err = (g + err) - deq
        comp_sum += deq
    # with EF the cumulative estimate stays within one quantization step
    resid = np.abs(true_sum - comp_sum).max()
    assert resid <= np.abs(np.asarray(err)).max() + 1e-5


def test_compression_wire_savings_math():
    from repro.optim.compression import CHUNK

    n = 1 << 20
    f32_bytes = 4 * n
    wire = n + 4 * (n // CHUNK)  # int8 payload + f32 scale per chunk
    assert wire < f32_bytes / 3.9


def test_train_crash_resume_equivalence():
    """Train 12 steps straight vs. train 8 + crash + resume-from-ckpt at 8
    — identical final loss trajectory (checkpoint captures params+opt,
    pipeline is seeded by step)."""
    from repro.launch.train import run

    store = CheckpointStore(DeltaStore(m=2, r=1, backend="mem"),
                            CheckpointConfig(snapshot_every=2))
    _, _, losses_a = run(arch="qwen3-1.7b", steps=12, batch=4, seq=32,
                         checkpoint_every=4, store=store, seed=11, log_every=100)
    # crash after step 7 (last save at step 7): fresh process resumes with
    # the SAME run config (steps=12 -> same LR schedule)
    store2 = CheckpointStore(DeltaStore(m=2, r=1, backend="mem"),
                             CheckpointConfig(snapshot_every=2))
    _, _, la = run(arch="qwen3-1.7b", steps=12, batch=4, seq=32,
                   checkpoint_every=4, store=store2, seed=11, log_every=100,
                   stop_after=8)
    _, _, lb = run(arch="qwen3-1.7b", steps=12, batch=4, seq=32,
                   checkpoint_every=4, store=store2, seed=11, resume=True,
                   log_every=100)
    np.testing.assert_allclose(losses_a[:8], la, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(losses_a[8:], lb, rtol=1e-5, atol=1e-6)
