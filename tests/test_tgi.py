"""TGI correctness: index-reconstructed state == naive full replay, for
snapshots, node histories, and k-hop neighborhoods — plus storage-layer
behaviors (replication failover, placement spread)."""
import numpy as np
import pytest

from repro.core.events import EventLog
from repro.core.snapshot import GraphState
from repro.core.tgi import TGI, TGIConfig
from repro.data.temporal_graph_gen import generate, naive_state_at
from repro.storage.kvstore import DeltaStore, StorageNodeDown

N_EVENTS = 6000


@pytest.fixture(scope="module")
def built():
    events = generate(N_EVENTS, seed=7)
    cfg = TGIConfig(n_shards=4, parts_per_shard=2, events_per_span=1500,
                    eventlist_size=128, checkpoints_per_span=4)
    store = DeltaStore(m=4, r=2, backend="mem")
    tgi = TGI.build(events, cfg, store)
    return events, cfg, store, tgi


def _states_equal(a: GraphState, b: GraphState):
    n = max(len(a.present), len(b.present))
    a.grow(n)
    b.grow(n)
    assert (a.present == b.present).all(), "presence mismatch"
    on = a.present == 1
    assert (a.attrs[on] == b.attrs[on]).all(), "attr mismatch"
    assert len(a.edge_key) == len(b.edge_key), (
        f"edge count {len(a.edge_key)} vs {len(b.edge_key)}"
    )
    assert (a.edge_key == b.edge_key).all()
    assert (a.edge_val == b.edge_val).all(), "edge attr mismatch"


@pytest.mark.parametrize("frac", [0.05, 0.3, 0.5, 0.77, 0.99])
def test_snapshot_matches_naive_replay(built, frac):
    events, cfg, store, tgi = built
    t0, t1 = events.time_range()
    t = int(t0 + frac * (t1 - t0))
    got = tgi.get_snapshot(t)
    want = naive_state_at(events, t, cfg.n_attrs)
    _states_equal(got, want)


def test_snapshot_parallel_fetch_equal(built):
    events, cfg, store, tgi = built
    t = int(np.mean(events.time_range()))
    a = tgi.get_snapshot(t, c=1)
    b = tgi.get_snapshot(t, c=4)
    _states_equal(a, b)


def test_snapshot_with_kernel_path(built):
    events, cfg, store, tgi = built
    t = int(np.mean(events.time_range()))
    a = tgi.get_snapshot(t, use_kernel=False)
    b = tgi.get_snapshot(t, use_kernel=True)
    _states_equal(a, b)


def test_node_history_matches_naive(built):
    events, cfg, store, tgi = built
    t0g, t1g = events.time_range()
    t0 = int(t0g + 0.3 * (t1g - t0g))
    t1 = int(t0g + 0.8 * (t1g - t0g))
    # pick active nodes
    want_state = naive_state_at(events, t0, cfg.n_attrs)
    nids = want_state.node_ids()[:5]
    for nid in nids:
        init, ev = tgi.get_node_history(int(nid), t0, t1)
        # init matches naive state at t0
        if want_state.present[nid]:
            assert init is not None
            assert (init["attrs"] == want_state.attrs[nid]).all()
            naive_neigh = set()
            src, dst, _ = want_state.edges()
            naive_neigh |= set(dst[src == nid].tolist())
            naive_neigh |= set(src[dst == nid].tolist())
            assert set(init["neighbors"].tolist()) == naive_neigh
        # events match naive filter
        sel = ((events.src == nid) | (events.dst == nid)) & (events.t > t0) & (events.t <= t1)
        want_ev = events.take(np.nonzero(sel)[0])
        assert len(ev) == len(want_ev)
        assert (ev.t == want_ev.t).all()
        assert (ev.kind == want_ev.kind).all()


@pytest.mark.parametrize("k,method", [(1, "expand"), (1, "snapshot"), (2, "expand")])
def test_k_hop_matches_filtered_snapshot(built, k, method):
    events, cfg, store, tgi = built
    t0g, t1g = events.time_range()
    t = int(t0g + 0.6 * (t1g - t0g))
    want_full = naive_state_at(events, t, cfg.n_attrs)
    deg = want_full.degree()
    nid = int(np.argmax(deg))  # a hub
    got = tgi.get_k_hop(nid, t, k, method=method)
    want = tgi._filter_k_hop(want_full, nid, k)
    _states_equal(got, want)


def test_1hop_history(built):
    events, cfg, store, tgi = built
    t0g, t1g = events.time_range()
    t0 = int(t0g + 0.4 * (t1g - t0g))
    t1 = int(t0g + 0.7 * (t1g - t0g))
    state = naive_state_at(events, t0, cfg.n_attrs)
    nid = int(np.argmax(state.degree()))
    out = tgi.get_node_1hop_history(nid, t0, t1)
    assert out["hood"].present[nid]
    for m, ev_m in out["neighbor_events"].items():
        sel = ((events.src == m) | (events.dst == m)) & (events.t > t0) & (events.t <= t1)
        assert len(ev_m) == int(sel.sum())


def test_replication_failover(built):
    events, cfg, store, tgi = built
    t = int(np.mean(events.time_range()))
    want = tgi.get_snapshot(t)
    store.stats.reset()
    store.fail_node(0)
    tgi.invalidate_caches()  # force a real storage read past the snapshot LRU
    try:
        got = tgi.get_snapshot(t)
        _states_equal(got, want)
        assert store.stats.failovers > 0
    finally:
        store.heal_node(0)


def test_all_replicas_down_raises():
    events = generate(800, seed=1)
    cfg = TGIConfig(n_shards=2, parts_per_shard=2, events_per_span=400,
                    eventlist_size=64, checkpoints_per_span=2)
    store = DeltaStore(m=2, r=1, backend="mem")
    tgi = TGI.build(events, cfg, store)
    store.fail_node(0)
    store.fail_node(1)
    with pytest.raises((StorageNodeDown, KeyError)):
        tgi.get_snapshot(int(np.mean(events.time_range())))


def test_incremental_update_equals_bulk_build():
    events = generate(4000, seed=3)
    half = len(events) // 2
    cfg = TGIConfig(n_shards=2, parts_per_shard=2, events_per_span=1000,
                    eventlist_size=100, checkpoints_per_span=2)
    s1 = DeltaStore(m=2, r=1, backend="mem")
    bulk = TGI.build(events, cfg, s1)
    s2 = DeltaStore(m=2, r=1, backend="mem")
    inc = TGI.build(events.take(slice(0, half)), cfg, s2)
    inc.update(events.take(slice(half, len(events))))
    t0, t1 = events.time_range()
    for frac in (0.25, 0.6, 0.95):
        t = int(t0 + frac * (t1 - t0))
        _states_equal(bulk.get_snapshot(t), inc.get_snapshot(t))


def test_locality_partitioning_build():
    events = generate(2500, seed=11)
    cfg = TGIConfig(n_shards=2, parts_per_shard=2, events_per_span=900,
                    eventlist_size=100, checkpoints_per_span=2,
                    partition_strategy="locality", replicate_1hop=True)
    store = DeltaStore(m=2, r=1, backend="mem")
    tgi = TGI.build(events, cfg, store)
    t0, t1 = events.time_range()
    t = int(t0 + 0.7 * (t1 - t0))
    _states_equal(tgi.get_snapshot(t), naive_state_at(events, t, cfg.n_attrs))


def test_file_backend_roundtrip(tmp_path):
    events = generate(1200, seed=5)
    cfg = TGIConfig(n_shards=2, parts_per_shard=2, events_per_span=600,
                    eventlist_size=64, checkpoints_per_span=2)
    store = DeltaStore(m=3, r=2, backend="file", root=str(tmp_path))
    tgi = TGI.build(events, cfg, store)
    t0, t1 = events.time_range()
    t = int(t0 + 0.8 * (t1 - t0))
    _states_equal(tgi.get_snapshot(t), naive_state_at(events, t, cfg.n_attrs))
    # and under single-node failure
    store.fail_node(1)
    _states_equal(tgi.get_snapshot(t), naive_state_at(events, t, cfg.n_attrs))
